//! PJRT/XLA execution engine (feature `xla`).
//!
//! Executes the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` through the `xla` crate's PJRT CPU client. This
//! is the original runtime path of the reproduction, preserved behind a
//! cargo feature because the `xla` crate (xla-rs + a pinned xla_extension)
//! is not available in the offline build environment; vendor it and build
//! with `--features xla` to re-enable. rust/DESIGN.md §2 documents the
//! engine seam.
//!
//! # Safety
//!
//! `PjRtClient`, `PjRtLoadedExecutable`, and `Literal` hold raw pointers and
//! internal `Rc`s, so the xla crate does not mark them `Send`. The
//! underlying XLA objects are plain heap allocations; the only hazards are
//! (a) unsynchronized `Rc` refcount updates and (b) concurrent mutation.
//! `Device` prevents both by construction: the engine is reachable only
//! through the bus `Mutex`, and no `Rc` clone or XLA call ever happens
//! outside that lock. Hence the manual `unsafe impl Send`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::engine::{EntrySchema, ExecutionEngine, Head};
use super::manifest::NetSpec;
use super::tensor::{DataView, HostTensor, TensorView};

pub struct XlaEngine {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Named ABI schema per loaded entry — validated on every execute so
    /// mis-shaped calls are refused by entry and field name instead of
    /// surfacing as PJRT shape errors (rust/DESIGN.md §16).
    schemas: BTreeMap<String, EntrySchema>,
    platform: String,
}

unsafe impl Send for XlaEngine {}

impl XlaEngine {
    pub fn new() -> Result<XlaEngine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let platform = client.platform_name();
        Ok(XlaEngine { client, executables: BTreeMap::new(), schemas: BTreeMap::new(), platform })
    }

    fn to_literal(view: &TensorView<'_>) -> Result<xla::Literal> {
        let dims: Vec<usize> = view.shape.clone();
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match view.data {
            DataView::U8(d) => (xla::ElementType::U8, d.to_vec()),
            DataView::F32(d) => (
                xla::ElementType::F32,
                d.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            DataView::I32(d) => (
                xla::ElementType::S32,
                d.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &dims, &bytes)
            .map_err(|e| anyhow!("literal from view: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        // All entry outputs in the artifact ABI are f32.
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal download: {e}"))?;
        Ok(HostTensor::f32(data, vec![]))
    }
}

impl ExecutionEngine for XlaEngine {
    fn platform_name(&self) -> &str {
        &self.platform
    }

    fn load_entry(&mut self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()> {
        if self.executables.contains_key(key) {
            return Ok(());
        }
        // The AOT artifacts lower only the dqn dense tail; refuse head
        // variants up front rather than executing the wrong graph.
        if !matches!(spec.head, Head::Dqn) {
            bail!(
                "XLA engine artifacts implement only the dqn head; entry {entry_name:?} of \
                 {:?} requires the native engine",
                spec.runtime_name()
            );
        }
        let schema = EntrySchema::derive(spec, entry_name)?;
        let path = &spec.entry(entry_name)?.file;
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))
            .with_context(|| "run `make artifacts` to (re)build HLO artifacts")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        self.executables.insert(key.to_string(), exe);
        self.schemas.insert(key.to_string(), schema);
        Ok(())
    }

    fn is_loaded(&self, key: &str) -> bool {
        self.executables.contains_key(key)
    }

    fn execute(&mut self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(key)
            .ok_or_else(|| anyhow!("executable {key:?} not loaded"))?;
        if let Some(schema) = self.schemas.get(key) {
            schema.validate_args(args)?;
        }
        // Upload inputs as Rust-owned device buffers and use `execute_b`.
        // NOTE: the crate's `execute(&[Literal])` path leaks every input
        // device buffer (its C++ shim `release()`s the uploads and never
        // frees them after Execute) — ~13 MB per train step. Owning the
        // `PjRtBuffer`s here lets Drop reclaim them (rust/DESIGN.md §2).
        let mut buffers = Vec::with_capacity(args.len());
        for view in args {
            let lit = Self::to_literal(view)?;
            buffers.push(
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("upload {key:?}: {e}"))?,
            );
        }
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("execute {key:?}: {e}"))?;
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("execute {key:?}: empty result"))?;
        let tuple = buffer
            .to_literal_sync()
            .map_err(|e| anyhow!("download {key:?}: {e}"))?;
        let mut tuple = tuple;
        let literals = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {key:?}: {e}"))?;
        if literals.is_empty() {
            bail!("execute {key:?}: empty tuple");
        }
        literals.iter().map(Self::from_literal).collect()
    }
}
