//! tempo-dqn launcher: the leader entrypoint + CLI.
//!
//! Subcommands:
//!   train      run one training experiment (mode/threads/game/net via flags)
//!   fleet      spawn N local sampler processes + host the learner (one box)
//!   fleet-learner  host the training machine for a sampler fleet (--bind)
//!   fleet-sampler  run sampler slots against a remote learner (--connect)
//!   run-suite  execute a TOML-declared multi-game campaign with checkpoints
//!   speedtest  regenerate Tables 1-3 (DES by default; --real for scaled live runs)
//!   suite      regenerate the Table 4 analog over the synthetic game suite
//!   anchors    measure the Random / Human-proxy score anchors per game
//!   serve      policy-serving daemon: newest checkpoint -> batched inference
//!   serve-probe    scripted client for a running serve daemon (CI smoke)
//!   config     print the resolved experiment configuration
//!   bench-compare  diff two BENCH_<pr>.json perf snapshots, fail on regressions
//!   help       this text

use std::sync::Arc;

use anyhow::Result;

use tempo_dqn::campaign::{summary_table, Campaign};
use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::{run_fleet_sampler, spawn_local_samplers, Coordinator, FleetOpts};
use tempo_dqn::env::GAMES;
use tempo_dqn::eval::{AnchorKind, Evaluator};
use tempo_dqn::hwsim::{simulate, CostModel, SimRun};
use tempo_dqn::metrics::GanttTrace;
use tempo_dqn::report::{table4, GameRow, RuntimeGrid};
use tempo_dqn::runtime::default_artifact_dir;
use tempo_dqn::serve::{ServeClient, ServeOpts, Server};
use tempo_dqn::util::cli::Args;

const HELP: &str = "\
tempo-dqn — fast DQN via Concurrent Training + Synchronized Execution
(Daley & Amato, 2021 reproduction; see rust/DESIGN.md)

USAGE:
  tempo-dqn <subcommand> [options]

SUBCOMMANDS:
  train      --preset paper|speedtest|smoke --config FILE --mode MODE
             --threads N --envs-per-thread B --steps N --game NAME
             --net tiny|small|nature --seed N --double --lr X
             --head dqn|dueling|c51 --atoms N --v-min X --v-max X
             --eval-period N --eval-seed N --learner-threads N
             --prefetch-batches N --kernel-mode deterministic|fast
             --replay-strategy uniform|proportional
             --per-alpha X --per-beta0 X --per-beta-anneal N --n-step N
             --ckpt-dir DIR --ckpt-period N --resume DIR
  fleet      (train options) --fleet-samplers N [--fleet-lag K]
             [--fleet-timeout-ms MS] [--bind ADDR] [--resume DIR]
             (spawns N local fleet-sampler processes against a private
             unix socket, then hosts the learner; one-box convenience
             wrapper over fleet-learner + fleet-sampler)
  fleet-learner  (train options) --bind tcp:HOST:PORT|unix:PATH
             --fleet-samplers N [--fleet-lag K] [--resume DIR]
  fleet-sampler  (train options) --connect tcp:HOST:PORT|unix:PATH
             (must be launched with the learner's exact experiment
             configuration — the handshake refuses mismatches by name)
  run-suite  --campaign FILE (TOML campaign: legs, order, ckpt_dir; see
             rust/src/campaign.rs for the format)
  speedtest  --threads 1,2,4,8 --steps N [--real] [--gantt] [--game NAME]
             [--envs-per-thread B] [--learner-threads N]
             [--prefetch-batches N] [--replay-strategy S] [--kernel-mode M]
             [--breakdown] [--breakdown-steps N] [--net tiny|small|nature]
             (--breakdown prints a per-phase train-step timing table:
             conv forward / conv backward / dense / rmsprop / assembly)
  suite      --steps N --threads N [--games a,b,c] [--episodes N]
             [--eval-seed N]
  anchors    [--games a,b,c] [--episodes N] [--eval-seed N]
  serve      --ckpt-dir DIR [--bind tcp:HOST:PORT|unix:PATH]
             [--serve-max-batch N] [--serve-flush-us US] [--serve-poll-ms MS]
             (daemon: restores the newest step_<N>/ checkpoint's theta,
             answers act/stats requests over the fleet wire protocol,
             hot-swaps when a newer checkpoint lands; runs until a client
             sends shutdown)
  serve-probe    --connect ADDR [--requests N] [--states-per-request N]
             [--seed N] [--ckpt-dir DIR] [--await-step N] [--timeout-ms MS]
             [--shutdown]
             (scripted client: sends deterministic pseudo-random states;
             with --ckpt-dir, checks the daemon's Q-rows bitwise against a
             local restore of the same checkpoint; --await-step polls
             stats until the daemon has hot-swapped that far)
  config     (same options as train; prints the resolved config)
  bench-compare  --prev FILE --cur FILE [--noise 0.30] (exit 1 if any bench
             mean regressed beyond the noise fraction; see README
             \"Perf trajectory\")

The coordinator runs W = --threads sampler threads with B =
--envs-per-thread environment streams each; synchronized modes batch all
W×B inferences into one device transaction per round (rust/DESIGN.md §5).
The learner shards each minibatch over --learner-threads compute lanes and
double-buffers replay batch assembly (--prefetch-batches, 0 = off); both
knobs are bit-exact — any setting reproduces the serial trajectory
(rust/DESIGN.md §9).

Replay sampling is pluggable (rust/DESIGN.md §11): --replay-strategy
uniform (default; with --n-step 1 bit-identical to the seed machine) or
proportional (deterministic prioritized replay: sum-tree priorities from
TD errors updated at window barriers, IS weights --per-alpha/--per-beta0
with beta annealed over --per-beta-anneal minibatches). --n-step N builds
N-step returns with episode-boundary-correct truncation under either
strategy; proportional trajectories are bit-identical across
learner-threads, prefetch settings, and checkpoint/resume
(tests/strategy_equivalence.rs).

--kernel-mode selects the native engine's kernel tier (rust/DESIGN.md
§12): deterministic (default; bit-pinned serial-order tiled kernels, the
golden reference) or fast (vectorized lane-reordered kernels under a
bounded, property-tested divergence contract — still bit-identical
run-to-run and across --learner-threads, but not vs deterministic).

Checkpointing (rust/DESIGN.md §10): --ckpt-dir enables periodic atomic
checkpoints at quiesce points (every --ckpt-period steps, rounded up to a
window boundary); --resume DIR reconstructs the exact machine from the
newest checkpoint and continues the same trajectory to the bit.

The fleet subcommands (rust/DESIGN.md §14) distribute the W sampler slots
over --fleet-samplers processes speaking a checksummed wire protocol
(mode concurrent only). --fleet-lag 0 (default) is the replicated tier:
bit-identical state digest to the single-process run. --fleet-lag K >= 1
is the relaxed tier: samplers act window j with the theta_minus broadcast
K window barriers earlier — a deterministic, reproducible, but different
trajectory.

serve (rust/DESIGN.md §15) turns a checkpoint directory into an inference
daemon: concurrent act requests micro-batch into single device
transactions (at most --serve-max-batch states, flushed --serve-flush-us
after the first rider), and a watcher hot-swaps theta when a newer valid
checkpoint lands — corrupt checkpoints are skipped with a named warning.
Batched rows are bit-identical to single-sample QNet::infer under the
same theta.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let result = match sub.as_str() {
        "train" => cmd_train(&args),
        "fleet" => cmd_fleet(&args),
        "fleet-learner" => cmd_fleet_learner(&args),
        "fleet-sampler" => cmd_fleet_sampler(&args),
        "run-suite" => cmd_run_suite(&args),
        "speedtest" => cmd_speedtest(&args),
        "suite" => cmd_suite(&args),
        "anchors" => cmd_anchors(&args),
        "serve" => cmd_serve(&args),
        "serve-probe" => cmd_serve_probe(&args),
        "config" => cmd_config(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_config(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    println!("{cfg:#?}");
    Ok(())
}

fn cmd_bench_compare(args: &Args) -> Result<()> {
    let Some(prev) = args.str_opt("prev") else {
        anyhow::bail!("bench-compare needs --prev FILE (the older BENCH_<pr>.json)");
    };
    let Some(cur) = args.str_opt("cur") else {
        anyhow::bail!("bench-compare needs --cur FILE (the fresh BENCH_<pr>.json)");
    };
    let noise = args.f64_or("noise", 0.30)?;
    let report = tempo_dqn::benchkit::compare_files(
        std::path::Path::new(prev),
        std::path::Path::new(cur),
        noise,
    )?;
    print!("{}", report.render());
    let n = report.regressions().len();
    if n > 0 {
        anyhow::bail!(
            "bench-compare: {n} regression(s) beyond the {:.0}% noise threshold",
            noise * 100.0
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    if cfg.fleet_lag > 0 {
        anyhow::bail!(
            "--fleet-lag {} is a fleet-only knob: single-process training has no \
             parameter transport to relax (use the fleet subcommands, or --fleet-lag 0)",
            cfg.fleet_lag
        );
    }
    println!(
        "training: game={} net={} mode={} threads={} envs/thread={} ({} streams) steps={} seed={}",
        cfg.game,
        cfg.net,
        cfg.mode.name(),
        cfg.threads,
        cfg.envs_per_thread,
        cfg.streams(),
        cfg.total_steps,
        cfg.seed
    );
    if let Some(dir) = &cfg.ckpt_dir {
        println!("checkpointing: dir={dir} period={} steps", cfg.ckpt_period);
    }
    let mut coord = Coordinator::new(cfg, &default_artifact_dir())?;
    if let Some(dir) = args.str_opt("resume") {
        let step = coord.resume_from(std::path::Path::new(dir))?;
        println!("resumed from {dir} at step {step}");
    }
    let res = coord.run()?;
    println!(
        "done: {} steps in {:.1}s ({:.1} steps/s), {} episodes, {} trains, {} target syncs",
        res.steps, res.wall_s, res.steps_per_sec, res.episodes, res.trains, res.target_syncs
    );
    println!(
        "bus: {} transactions, {:.1} MB in, {:.1} MB out",
        res.bus.transactions,
        res.bus.bytes_in as f64 / 1e6,
        res.bus.bytes_out as f64 / 1e6
    );
    if let Some((step, loss)) = res.losses.last() {
        println!("final loss sample: {loss:.5} @ step {step}");
    }
    println!("recent mean return: {:.2}", res.recent_mean_return(20));
    for ev in &res.evals {
        println!(
            "eval @ {}: {:.1} ± {:.1} over {} episodes",
            ev.step, ev.mean_return, ev.std_return, ev.episodes
        );
    }
    // Trajectory fingerprint over params/optimizer/replay/RNG streams —
    // two runs on the same trajectory print the same digest (the CI
    // resume-smoke compares an uninterrupted run against ckpt + resume).
    println!("state digest: {:016x}", coord.state_digest()?);
    print!("{}", res.timers_report);
    Ok(())
}

/// The common tail of every learner-side run: result summary + the
/// trajectory fingerprint (tests and the CI fleet smoke compare the
/// digest line across fleet and single-process runs).
fn report_learner_result(
    coord: &Coordinator,
    res: &tempo_dqn::coordinator::TrainResult,
) -> Result<()> {
    println!(
        "done: {} steps in {:.1}s ({:.1} steps/s), {} episodes, {} trains, {} target syncs",
        res.steps, res.wall_s, res.steps_per_sec, res.episodes, res.trains, res.target_syncs
    );
    for ev in &res.evals {
        println!(
            "eval @ {}: {:.1} ± {:.1} over {} episodes",
            ev.step, ev.mean_return, ev.std_return, ev.episodes
        );
    }
    println!("state digest: {:016x}", coord.state_digest()?);
    Ok(())
}

fn cmd_fleet_learner(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    let Some(bind) = args.str_opt("bind") else {
        anyhow::bail!("fleet-learner needs --bind tcp:HOST:PORT or unix:PATH");
    };
    if cfg.fleet_samplers == 0 {
        anyhow::bail!("fleet-learner needs --fleet-samplers N >= 1 (connections to accept)");
    }
    let opts = FleetOpts { bind: bind.to_string(), samplers: cfg.fleet_samplers };
    println!(
        "fleet learner: game={} mode={} W={} B={} steps={} seed={} samplers={} lag={}",
        cfg.game,
        cfg.mode.name(),
        cfg.threads,
        cfg.envs_per_thread,
        cfg.total_steps,
        cfg.seed,
        opts.samplers,
        cfg.fleet_lag
    );
    let mut coord = Coordinator::new(cfg, &default_artifact_dir())?;
    if let Some(dir) = args.str_opt("resume") {
        let step = coord.resume_from(std::path::Path::new(dir))?;
        println!("resumed from {dir} at step {step}");
    }
    let res = coord.run_fleet(&opts, None)?;
    report_learner_result(&coord, &res)
}

fn cmd_fleet_sampler(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    let Some(connect) = args.str_opt("connect") else {
        anyhow::bail!("fleet-sampler needs --connect ADDR (the learner's --bind address)");
    };
    run_fleet_sampler(&cfg, connect, &default_artifact_dir())
}

/// One-box convenience: spawn `--fleet-samplers` local sampler worker
/// processes of this very binary against a private endpoint, then host
/// the learner. The workers retry-connect until the learner binds, so
/// spawn order doesn't matter.
fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    if cfg.fleet_samplers == 0 {
        anyhow::bail!("fleet needs --fleet-samplers N >= 1 (local sampler processes to spawn)");
    }
    let samplers = cfg.fleet_samplers;
    let bind = match args.str_opt("bind") {
        Some(addr) => addr.to_string(),
        None => default_fleet_bind()?,
    };
    println!(
        "fleet: game={} mode={} W={} B={} steps={} seed={} samplers={} lag={} at {bind}",
        cfg.game,
        cfg.mode.name(),
        cfg.threads,
        cfg.envs_per_thread,
        cfg.total_steps,
        cfg.seed,
        samplers,
        cfg.fleet_lag
    );
    let bin = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving our own binary for sampler spawns: {e}"))?;
    let mut children = spawn_local_samplers(&bin, &cfg, &bind, samplers)?;
    let mut coord = Coordinator::new(cfg, &default_artifact_dir())?;
    let run = (|| -> Result<tempo_dqn::coordinator::TrainResult> {
        if let Some(dir) = args.str_opt("resume") {
            let step = coord.resume_from(std::path::Path::new(dir))?;
            println!("resumed from {dir} at step {step}");
        }
        coord.run_fleet(&FleetOpts { bind: bind.clone(), samplers }, None)
    })();
    // Reap the workers: a clean run shut them down over the wire; on
    // error they may be blocked (or still retrying the connect), so kill
    // before waiting.
    if run.is_err() {
        for child in &mut children {
            let _ = child.kill();
        }
    }
    for child in &mut children {
        let _ = child.wait();
    }
    report_learner_result(&coord, &run?)
}

/// A private per-process endpoint whose address is known before the
/// learner binds it: a unix socket in a fresh temp directory (TCP
/// loopback fallback where unix sockets don't exist).
fn default_fleet_bind() -> Result<String> {
    #[cfg(unix)]
    {
        let dir = std::env::temp_dir().join(format!("tempo-fleet-{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        Ok(format!("unix:{}", dir.join("fleet.sock").display()))
    }
    #[cfg(not(unix))]
    {
        Ok(format!("tcp:127.0.0.1:{}", 40_000 + std::process::id() % 20_000))
    }
}

/// A private per-process endpoint for a serve daemon started without
/// --bind (mainly tests and one-box smoke runs; real deployments pass an
/// explicit address).
fn default_serve_bind() -> Result<String> {
    #[cfg(unix)]
    {
        let dir = std::env::temp_dir().join(format!("tempo-serve-{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        Ok(format!("unix:{}", dir.join("serve.sock").display()))
    }
    #[cfg(not(unix))]
    {
        Ok(format!("tcp:127.0.0.1:{}", 41_000 + std::process::id() % 20_000))
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::resolve(args)?;
    let Some(dir) = cfg.ckpt_dir.clone() else {
        anyhow::bail!("serve needs --ckpt-dir DIR (the checkpoint directory to serve from)");
    };
    let bind = match args.str_opt("bind") {
        Some(addr) => addr.to_string(),
        None => default_serve_bind()?,
    };
    let opts = ServeOpts::from_config(&cfg);
    let handle = Server::start(
        std::path::Path::new(&dir),
        &default_artifact_dir(),
        &bind,
        opts,
    )?;
    println!(
        "serving {dir} at {} (step {}, max-batch {}, flush {}us, poll {}ms)",
        handle.addr(),
        handle.stats().step,
        cfg.serve_max_batch,
        cfg.serve_flush_us,
        cfg.serve_poll_ms
    );
    handle.wait()?;
    println!("serve: stopped");
    Ok(())
}

fn cmd_serve_probe(args: &Args) -> Result<()> {
    use tempo_dqn::env::STATE_BYTES;
    use tempo_dqn::runtime::Policy;

    let Some(connect) = args.str_opt("connect") else {
        anyhow::bail!("serve-probe needs --connect ADDR (the daemon's --bind address)");
    };
    let requests = args.usize_or("requests", 16)?;
    let per = args.usize_or("states-per-request", 2)?;
    let timeout = std::time::Duration::from_millis(args.u64_or("timeout-ms", 10_000)?);
    let await_step = args.u64_or("await-step", 0)?;
    let mut client = ServeClient::connect(connect, timeout)?;

    // Optional bitwise reference: restore the same checkpoint this process
    // and compare the daemon's Q-rows against direct single-sample infer.
    let local = match args.str_opt("ckpt-dir") {
        Some(dir) => {
            let dir = std::path::Path::new(dir);
            let reader = tempo_dqn::ckpt::open_latest(dir)?.ok_or_else(|| {
                anyhow::anyhow!("serve-probe --ckpt-dir: no checkpoint under {}", dir.display())
            })?;
            let mut r = reader.read_section("qnet", 1)?;
            let t = tempo_dqn::runtime::QNetTheta::decode(&mut r)?;
            let manifest = tempo_dqn::runtime::Manifest::load_or_builtin(&default_artifact_dir())?;
            let device = Arc::new(tempo_dqn::runtime::Device::cpu()?);
            // The checkpoint name carries the head tag; split it so the
            // probe's reference QNet runs the same head as the daemon.
            let (base, head) = tempo_dqn::runtime::Head::split(&t.name)?;
            let qnet =
                tempo_dqn::runtime::QNet::load_with_head(device, &manifest, &base, t.double, 32, head)?;
            qnet.set_theta(&t.theta)?;
            Some((reader.step(), qnet))
        }
        None => None,
    };

    let mut rng: u64 = 0x9E37_79B9_7F4A_7C15 ^ args.u64_or("seed", 1)?;
    let mut compared = 0usize;
    let mut mismatches = 0usize;
    for _ in 0..requests {
        let mut states = vec![0u8; per * STATE_BYTES];
        for px in states.iter_mut() {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *px = (rng >> 56) as u8;
        }
        let reply = client.act(&states, per)?;
        if let Some((step, qnet)) = &local {
            // Only rows served under the locally loaded step are
            // comparable; a mid-probe hot-swap makes later replies newer.
            if reply.step == *step {
                let actions = qnet.spec().actions;
                for j in 0..per {
                    let row =
                        qnet.infer(Policy::Theta, &states[j * STATE_BYTES..(j + 1) * STATE_BYTES], 1)?;
                    let want: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
                    let got: Vec<u32> = reply.q[j * actions..(j + 1) * actions]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    let act_ok = reply.actions[j] as usize == tempo_dqn::agent::argmax(&row);
                    if got == want && act_ok {
                        compared += 1;
                    } else {
                        mismatches += 1;
                    }
                }
            }
        }
    }
    if local.is_some() {
        println!("serve-probe: {compared} rows bit-exact, {mismatches} mismatches");
        if mismatches > 0 {
            anyhow::bail!("serve-probe: {mismatches} row(s) diverged from direct QNet::infer");
        }
        if compared == 0 {
            anyhow::bail!(
                "serve-probe: no rows compared — the daemon already serves a newer \
                 step than the local checkpoint restore"
            );
        }
    }

    if await_step > 0 {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let s = client.stats()?;
            if s.step >= await_step {
                println!(
                    "serve-probe: daemon reached step {} after {} swap(s)",
                    s.step, s.swaps
                );
                break;
            }
            if std::time::Instant::now() >= deadline {
                anyhow::bail!(
                    "serve-probe: daemon never reached step {await_step} (still at {})",
                    s.step
                );
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
    }

    let s = client.stats()?;
    println!(
        "serve-probe: daemon stats: requests={} states={} step={} swaps={} skips={} \
         lat p50={}us p90={}us p99={}us max={}us",
        s.requests, s.states, s.step, s.swaps, s.swap_skips,
        s.lat_us[0], s.lat_us[1], s.lat_us[2], s.lat_us[3]
    );
    if args.flag("shutdown") {
        client.shutdown("serve-probe --shutdown")?;
        println!("serve-probe: shutdown sent");
    }
    Ok(())
}

fn cmd_run_suite(args: &Args) -> Result<()> {
    let Some(path) = args.str_opt("campaign") else {
        anyhow::bail!("run-suite needs --campaign FILE (TOML; see rust/src/campaign.rs)");
    };
    let campaign = Campaign::load(std::path::Path::new(path))?;
    println!(
        "campaign {:?}: {} legs, order {:?}, checkpoints under {}",
        campaign.name,
        campaign.legs.len(),
        campaign.order,
        campaign.ckpt_root.display()
    );
    let reports = campaign.run(&default_artifact_dir(), |line| println!("{line}"))?;
    print!("{}", summary_table(&reports));
    Ok(())
}

fn cmd_speedtest(args: &Args) -> Result<()> {
    let threads = args.usize_list_or("threads", &[1, 2, 4, 8])?;
    let real = args.flag("real");
    let steps = args.u64_or("steps", if real { 2_000 } else { 1_000_000 })?;
    let game = args.get_or("game", "pong").to_string();
    let learner_threads = args.usize_or("learner-threads", 1)?;
    let prefetch_batches = args.usize_or("prefetch-batches", 1)?;
    let replay_strategy =
        tempo_dqn::config::ReplayStrategy::parse(args.get_or("replay-strategy", "uniform"))?;
    let prioritized = replay_strategy == tempo_dqn::config::ReplayStrategy::Proportional;
    let kernel_mode =
        tempo_dqn::runtime::KernelMode::parse(args.get_or("kernel-mode", "deterministic"))?;

    // DES reproduction of the paper's grid (scaled to 50M steps like the
    // paper's x50 extrapolation of a 1M-step measurement).
    let model = CostModel::gtx1080_i7();
    let mut grid = RuntimeGrid::new(&threads);
    for &w in &threads {
        for mode in ExecMode::ALL {
            let run = SimRun {
                steps: steps.min(1_000_000),
                c: 10_000,
                f: 4,
                threads: w,
                learner_threads,
                prefetch: prefetch_batches > 0,
                prioritized,
                fleet_procs: 0,
            };
            let stats = simulate(model, run, mode);
            let hours = stats.makespan_ms * (50_000_000.0 / run.steps as f64) / 3_600_000.0;
            grid.set(mode, w, hours, 0.0);
        }
    }
    println!("== simulated machine: GTX 1080 + i7-7700K cost model ==");
    print!("{}", grid.table1());
    print!("{}", grid.table2());
    print!("{}", grid.table3());
    if let Some((base, best, speedup)) = grid.headline() {
        println!("headline: {base:.2} h -> {best:.2} h ({speedup:.2}x)\n");
    }

    if real {
        let envs_per_thread = args.usize_or("envs-per-thread", 1)?;
        println!(
            "== real scaled runs on this machine ({steps} steps, {game}, B={envs_per_thread}) =="
        );
        let mut rgrid = RuntimeGrid::new(&threads);
        for &w in &threads {
            for mode in ExecMode::ALL {
                let mut cfg = ExperimentConfig::preset("speedtest")?;
                cfg.game = game.clone();
                cfg.net = args.get_or("net", "tiny").to_string();
                cfg.mode = mode;
                cfg.threads = w;
                cfg.envs_per_thread = envs_per_thread;
                cfg.learner_threads = learner_threads;
                cfg.prefetch_batches = prefetch_batches;
                cfg.kernel_mode = kernel_mode;
                cfg.replay_strategy = replay_strategy;
                cfg.total_steps = steps;
                cfg.prepopulate = 1_000.min(steps as usize);
                cfg.replay_capacity = 100_000;
                cfg.target_update_period = args.u64_or("target-period", 1_000)?;
                let mut coord = Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
                let res = coord.run()?;
                let hours = res.wall_s / 3_600.0;
                println!(
                    "  {:>12} W={w}: {:.1}s ({:.1} steps/s, {} txns)",
                    mode.name(), res.wall_s, res.steps_per_sec, res.bus.transactions
                );
                rgrid.set(mode, w, hours, 0.0);
            }
        }
        print!("{}", rgrid.table3());
    }

    if args.flag("breakdown") {
        // Per-phase timing of the native train step (rust/DESIGN.md §13):
        // drive the real train entry through QNet against a synthetic
        // replay ring, with the engine's TrainTimers attached so the
        // kernel-level split (conv fwd / conv bwd / dense / rmsprop) and
        // the host-side batch assembly are visible without a profiler.
        let net = args.get_or("net", "tiny").to_string();
        let bd_steps = args.usize_or("breakdown-steps", 64)?;
        let mode_name = args.get_or("kernel-mode", "deterministic").to_string();
        println!(
            "== train-step phase breakdown ({net}, {bd_steps} steps, \
             kernel-mode {mode_name}, learner-threads {learner_threads}) =="
        );
        let timers = Arc::new(tempo_dqn::metrics::TrainTimers::new());
        let mut engine = tempo_dqn::runtime::NativeEngine::with_options(learner_threads, kernel_mode);
        engine.set_train_timers(timers.clone());
        let device = Arc::new(tempo_dqn::runtime::Device::with_engine(Box::new(engine)));
        let manifest = tempo_dqn::runtime::Manifest::load_or_builtin(&default_artifact_dir())?;
        let qnet = tempo_dqn::runtime::QNet::load(device, &manifest, &net, false, 32)?;

        // Deterministic pseudo-random replay contents (LCG high bytes) —
        // phase shares depend only on geometry, not on pixel statistics.
        let [h, w, stack] = qnet.spec().frame;
        let actions = qnet.spec().actions;
        let mut replay = tempo_dqn::replay::ReplayMemory::new(2_048, 1, h * w, stack, 7)?;
        let mut frame = vec![0u8; h * w];
        let mut rng: u64 = 0x9E37_79B9_7F4A_7C15;
        for t in 0..1_100usize {
            for px in frame.iter_mut() {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *px = (rng >> 56) as u8;
            }
            let done = t % 200 == 199;
            replay.push(0, &frame, (t % actions) as u8, (t % 3) as f32, done, t == 0);
        }

        let mut batch = tempo_dqn::runtime::TrainBatch::default();
        for _ in 0..bd_steps {
            timers.time(tempo_dqn::metrics::TrainPhase::Assembly, || {
                replay.sample(32, &mut batch)
            })?;
            qnet.train_step(&batch, 2.5e-4)?;
        }
        print!("{}", timers.report());
        println!(
            "(sharded phases accumulate per-worker CPU time; shares within \
             the table stay comparable)"
        );
    }

    if args.flag("gantt") {
        println!("== measured timing diagram (Figure 2 analog) ==");
        let gantt = Arc::new(GanttTrace::new(200_000));
        let mut cfg = ExperimentConfig::preset("smoke")?;
        cfg.game = game;
        cfg.mode = ExecMode::parse(args.get_or("mode", "both"))?;
        cfg.threads = *threads.last().unwrap_or(&4);
        cfg.total_steps = args.u64_or("gantt-steps", 256)?;
        let mut coord =
            Coordinator::new(cfg, &default_artifact_dir())?.with_gantt(gantt.clone());
        coord.run()?;
        print!("{}", gantt.render_ascii(100));
    }
    Ok(())
}

fn cmd_anchors(args: &Args) -> Result<()> {
    let games: Vec<String> = match args.str_opt("games") {
        Some(list) => list.split(',').map(str::to_string).collect(),
        None => GAMES.iter().map(|s| s.to_string()).collect(),
    };
    let episodes = args.usize_or("episodes", 10)?;
    let max_steps = args.usize_or("max-steps", 3_000)?;
    let eval_seed = args.u64_or("eval-seed", ExperimentConfig::default().eval_seed)?;
    println!("{:<10} {:>12} {:>12}", "game", "random", "human-proxy");
    for game in &games {
        let mut ev = Evaluator::new(game, eval_seed, episodes, 0.05)?.with_max_steps(max_steps);
        let rand = ev.run_anchor(AnchorKind::Random)?;
        let expert = ev.run_anchor(AnchorKind::Expert)?;
        println!(
            "{game:<10} {:>7.1}±{:<5.1} {:>7.1}±{:<5.1}",
            rand.mean_return, rand.std_return, expert.mean_return, expert.std_return
        );
    }
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let games: Vec<String> = match args.str_opt("games") {
        Some(list) => list.split(',').map(str::to_string).collect(),
        None => GAMES.iter().map(|s| s.to_string()).collect(),
    };
    let steps = args.u64_or("steps", 3_000)?;
    let threads = args.usize_or("threads", 4)?;
    let episodes = args.usize_or("episodes", 5)?;
    let max_steps = args.usize_or("max-steps", 2_000)?;
    let net = args.get_or("net", "tiny").to_string();
    let eval_seed = args.u64_or("eval-seed", ExperimentConfig::default().eval_seed)?;

    let mut rows = Vec::new();
    for game in &games {
        println!("[suite] {game}: anchors...");
        let mut ev = Evaluator::new(game, eval_seed, episodes, 0.05)?.with_max_steps(max_steps);
        let random = ev.run_anchor(AnchorKind::Random)?;
        let human = ev.run_anchor(AnchorKind::Expert)?;

        let train_score = |mode: ExecMode, w: usize| -> Result<f64> {
            let mut cfg = ExperimentConfig::preset("smoke")?;
            cfg.game = game.clone();
            cfg.net = net.clone();
            cfg.mode = mode;
            cfg.threads = w;
            cfg.total_steps = steps;
            cfg.prepopulate = 1_000.min(steps as usize / 2 + 1);
            cfg.replay_capacity = 120_000;
            cfg.target_update_period = 500;
            cfg.eps = tempo_dqn::config::EpsSchedule {
                start: 1.0,
                end: 0.1,
                decay_steps: steps / 2,
            };
            let mut coord = Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
            coord.run()?;
            // Post-training scoring uses its own seed derived from the
            // eval seed (+92 keeps the historical default of 99).
            let mut ev2 = Evaluator::new(game, eval_seed.wrapping_add(92), episodes, 0.05)?
                .with_max_steps(max_steps);
            Ok(ev2.run(coord.qnet(), steps)?.mean_return)
        };
        println!("[suite] {game}: training standard-DQN baseline...");
        let baseline = train_score(ExecMode::Standard, 1)?;
        println!("[suite] {game}: training tempo-dqn (both, W={threads})...");
        let ours = train_score(ExecMode::Both, threads)?;
        rows.push(GameRow { game: game.clone(), random, human, baseline_dqn: baseline, ours });
    }
    print!("{}", table4(&rows));
    Ok(())
}
