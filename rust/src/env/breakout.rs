//! Breakout-like game: paddle, ball, brick wall, 5 lives.
//!
//! Actions: 0 = NOOP, 1 = LEFT, 2 = RIGHT, 3 = FIRE (serve).
//! Reward +1 per brick (higher rows are worth more raw points, clipped by
//! the preprocessing layer like the real DQN setup). Losing the ball costs
//! a life; the episode ends at 0 lives or when the wall is cleared twice.

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const COLS: usize = 12;
const ROWS: usize = 6;
const BRICK_W: f64 = RAW as f64 / COLS as f64;
const BRICK_H: f64 = 6.0;
const WALL_TOP: f64 = 24.0;
const PADDLE_W: f64 = 22.0;
const PADDLE_Y: f64 = (RAW - 10) as f64;
const BALL: f64 = 2.5;

pub struct Breakout {
    rng: Rng,
    bricks: [[bool; COLS]; ROWS],
    ball_x: f64,
    ball_y: f64,
    vel_x: f64,
    vel_y: f64,
    paddle_x: f64,
    lives: u32,
    serving: bool,
    walls_cleared: u32,
}

impl Breakout {
    pub fn new() -> Self {
        let mut b = Breakout {
            rng: Rng::new(0),
            bricks: [[true; COLS]; ROWS],
            ball_x: 0.0,
            ball_y: 0.0,
            vel_x: 0.0,
            vel_y: 0.0,
            paddle_x: RAW as f64 / 2.0,
            lives: 5,
            serving: true,
            walls_cleared: 0,
        };
        b.reset(0);
        b
    }

    fn serve(&mut self) {
        self.ball_x = self.paddle_x;
        self.ball_y = PADDLE_Y - 6.0;
        let angle = self.rng.range_f32(-0.7, 0.7) as f64;
        let speed = 2.6;
        self.vel_x = speed * angle.sin();
        self.vel_y = -speed * angle.cos();
        self.serving = false;
    }

    fn wall_remaining(&self) -> usize {
        self.bricks.iter().flatten().filter(|&&b| b).count()
    }
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Breakout {
    fn name(&self) -> &'static str {
        "breakout"
    }

    fn num_actions(&self) -> usize {
        4
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x42524b); // "BRK"
        self.bricks = [[true; COLS]; ROWS];
        self.paddle_x = RAW as f64 / 2.0;
        self.lives = 5;
        self.serving = true;
        self.walls_cleared = 0;
        self.ball_x = self.paddle_x;
        self.ball_y = PADDLE_Y - 6.0;
        self.vel_x = 0.0;
        self.vel_y = 0.0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        const PSPEED: f64 = 2.8;
        match action {
            1 => self.paddle_x = (self.paddle_x - PSPEED).max(PADDLE_W / 2.0),
            2 => self.paddle_x = (self.paddle_x + PSPEED).min(RAW as f64 - PADDLE_W / 2.0),
            3 if self.serving => self.serve(),
            _ => {}
        }
        if self.serving {
            // Ball rides the paddle until FIRE.
            self.ball_x = self.paddle_x;
            return StepResult { reward: 0.0, done: false };
        }

        self.ball_x += self.vel_x;
        self.ball_y += self.vel_y;

        if self.ball_x < BALL {
            self.ball_x = BALL;
            self.vel_x = self.vel_x.abs();
        }
        if self.ball_x > RAW as f64 - BALL {
            self.ball_x = RAW as f64 - BALL;
            self.vel_x = -self.vel_x.abs();
        }
        if self.ball_y < BALL {
            self.ball_y = BALL;
            self.vel_y = self.vel_y.abs();
        }

        let mut reward = 0.0;
        // Brick collisions.
        if self.ball_y >= WALL_TOP && self.ball_y < WALL_TOP + ROWS as f64 * BRICK_H {
            let row = ((self.ball_y - WALL_TOP) / BRICK_H) as usize;
            let col = ((self.ball_x / BRICK_W) as usize).min(COLS - 1);
            if row < ROWS && self.bricks[row][col] {
                self.bricks[row][col] = false;
                // Top rows score more (like Atari Breakout's tiers).
                reward = (ROWS - row) as f64;
                self.vel_y = -self.vel_y;
            }
        }
        if self.wall_remaining() == 0 {
            self.bricks = [[true; COLS]; ROWS];
            self.walls_cleared += 1;
        }

        // Paddle collision.
        if self.ball_y >= PADDLE_Y - BALL
            && self.vel_y > 0.0
            && (self.ball_x - self.paddle_x).abs() < PADDLE_W / 2.0 + BALL
        {
            self.vel_y = -self.vel_y.abs();
            self.vel_x += 0.6 * (self.ball_x - self.paddle_x) / (PADDLE_W / 2.0);
            self.vel_x = self.vel_x.clamp(-3.2, 3.2);
        }

        // Ball lost.
        let mut done = false;
        if self.ball_y > RAW as f64 {
            self.lives -= 1;
            if self.lives == 0 {
                done = true;
            } else {
                self.serving = true;
                self.ball_x = self.paddle_x;
                self.ball_y = PADDLE_Y - 6.0;
            }
        }
        if self.walls_cleared >= 2 {
            done = true;
        }
        StepResult { reward, done }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 12);
        for (r, row) in self.bricks.iter().enumerate() {
            for (c, &alive) in row.iter().enumerate() {
                if alive {
                    let shade = 200 - (r as u8) * 18;
                    draw::rect(
                        buf,
                        c as f64 * BRICK_W + 1.0,
                        WALL_TOP + r as f64 * BRICK_H + 1.0,
                        BRICK_W - 2.0,
                        BRICK_H - 2.0,
                        shade,
                    );
                }
            }
        }
        draw::rect(buf, self.paddle_x - PADDLE_W / 2.0, PADDLE_Y, PADDLE_W, 4.0, 255);
        draw::square(buf, self.ball_x, self.ball_y, BALL, 240);
        // Lives indicator.
        for i in 0..self.lives {
            draw::rect(buf, 2.0 + i as f64 * 6.0, 2.0, 4.0, 4.0, 255);
        }
    }

    fn expert_action(&mut self) -> usize {
        if self.serving {
            return 3;
        }
        // Predict where the ball lands; lead it.
        let target = if self.vel_y > 0.0 {
            self.ball_x + self.vel_x * ((PADDLE_Y - self.ball_y) / self.vel_y.max(0.1))
        } else {
            self.ball_x
        };
        let target = target.clamp(0.0, RAW as f64);
        if target < self.paddle_x - 3.0 {
            1
        } else if target > self.paddle_x + 3.0 {
            2
        } else {
            0
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        for row in &self.bricks {
            w.put_bool_slice(row);
        }
        w.put_f64(self.ball_x);
        w.put_f64(self.ball_y);
        w.put_f64(self.vel_x);
        w.put_f64(self.vel_y);
        w.put_f64(self.paddle_x);
        w.put_u32(self.lives);
        w.put_bool(self.serving);
        w.put_u32(self.walls_cleared);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        for row in &mut self.bricks {
            let v = r.bool_vec()?;
            if v.len() != COLS {
                anyhow::bail!("breakout: brick row has {} cells, want {COLS}", v.len());
            }
            row.copy_from_slice(&v);
        }
        self.ball_x = r.f64()?;
        self.ball_y = r.f64()?;
        self.vel_x = r.f64()?;
        self.vel_y = r.f64()?;
        self.paddle_x = r.f64()?;
        self.lives = r.u32()?;
        self.serving = r.bool()?;
        self.walls_cleared = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::game::RAW_FRAME;

    fn play(expert: bool, seed: u64, max_steps: usize) -> (f64, bool) {
        let mut g = Breakout::new();
        g.reset(seed);
        let mut total = 0.0;
        for _ in 0..max_steps {
            let a = if expert { g.expert_action() } else { 3 };
            let r = g.step(a);
            total += r.reward;
            if r.done {
                return (total, true);
            }
        }
        (total, false)
    }

    #[test]
    fn passive_player_loses_lives() {
        let (_score, done) = play(false, 1, 100_000);
        assert!(done, "serving+noop must eventually lose 5 lives");
    }

    #[test]
    fn expert_scores_well() {
        let (expert_score, _) = play(true, 2, 20_000);
        let (noop_score, _) = play(false, 2, 20_000);
        assert!(expert_score > noop_score + 10.0,
                "expert {expert_score} vs noop {noop_score}");
    }

    #[test]
    fn bricks_disappear_and_reward() {
        let mut g = Breakout::new();
        g.reset(3);
        let before = g.wall_remaining();
        let mut got_reward = false;
        for _ in 0..5_000 {
            let a = g.expert_action();
            if g.step(a).reward > 0.0 {
                got_reward = true;
                break;
            }
        }
        assert!(got_reward);
        assert!(g.wall_remaining() < before);
    }

    #[test]
    fn render_is_valid() {
        let mut g = Breakout::new();
        g.reset(4);
        let mut buf = vec![0u8; RAW_FRAME];
        g.render(&mut buf);
        assert!(buf.iter().any(|&b| b == 255));
    }
}
