//! The raw game interface: the synthetic stand-in for an Atari 2600 ROM.
//!
//! A `Game` simulates one emulator: it advances by one *raw* tick per
//! `step`, renders a raw grayscale screen, and reports un-clipped rewards.
//! Frame-skip, max-pooling, downscaling, frame stacking, and reward
//! clipping all live in [`crate::env::atari::AtariEnv`], exactly mirroring
//! the DQN preprocessing pipeline the paper inherits from Mnih et al.
//! (2015) — so the per-step CPU cost profile (simulate + render +
//! preprocess) matches the code path the paper schedules around.

/// Raw screen resolution (downscaled 2x to the network's 84x84).
pub const RAW: usize = 168;
/// Bytes in one raw frame.
pub const RAW_FRAME: usize = RAW * RAW;

/// Result of one raw tick.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepResult {
    /// Un-clipped game reward for this tick.
    pub reward: f64,
    /// Episode terminated (all lives lost / game over / win).
    pub done: bool,
}

use crate::ckpt::{ByteReader, ByteWriter};

/// One synthetic Atari-like game.
pub trait Game: Send {
    /// Stable identifier used by the registry and reports.
    fn name(&self) -> &'static str;

    /// Serialize the full mid-episode simulator state (including the RNG
    /// stream position) through the bit-exact checkpoint codec. Together
    /// with [`Game::load_state`] this must satisfy: save → load → step*
    /// produces exactly the frames/rewards the uninterrupted game would
    /// (rust/DESIGN.md §10).
    fn save_state(&self, w: &mut ByteWriter);

    /// Restore a state written by [`Game::save_state`].
    fn load_state(&mut self, r: &mut ByteReader<'_>) -> anyhow::Result<()>;

    /// Number of legal actions (<= 6; action 0 is always NOOP).
    fn num_actions(&self) -> usize;

    /// Reset to a fresh episode with deterministic randomness.
    fn reset(&mut self, seed: u64);

    /// Advance one raw tick under `action`.
    fn step(&mut self, action: usize) -> StepResult;

    /// Render the current raw grayscale screen into `buf` (RAW_FRAME bytes).
    fn render(&self, buf: &mut [u8]);

    /// Scripted competent policy — the "human-proxy" score anchor used by
    /// the Table 4 reproduction (see rust/DESIGN.md §3).
    fn expert_action(&mut self) -> usize;

    /// Reference score anchors (random, human-proxy), measured offline and
    /// recorded here so normalized scores are stable across runs.
    /// Returns None when anchors should be measured live instead.
    fn score_anchors(&self) -> Option<(f64, f64)> {
        None
    }
}

/// Simple drawing helpers shared by the game renderers.
pub mod draw {
    use super::{RAW, RAW_FRAME};

    /// Fill the whole screen with one intensity.
    pub fn clear(buf: &mut [u8], intensity: u8) {
        debug_assert_eq!(buf.len(), RAW_FRAME);
        buf.fill(intensity);
    }

    /// Filled axis-aligned rectangle; clipped to the screen.
    pub fn rect(buf: &mut [u8], x: f64, y: f64, w: f64, h: f64, intensity: u8) {
        let x0 = x.max(0.0) as usize;
        let y0 = y.max(0.0) as usize;
        let x1 = ((x + w).max(0.0) as usize).min(RAW);
        let y1 = ((y + h).max(0.0) as usize).min(RAW);
        for yy in y0..y1 {
            let row = &mut buf[yy * RAW..yy * RAW + RAW];
            for cell in &mut row[x0.min(RAW)..x1] {
                *cell = intensity;
            }
        }
    }

    /// Filled square centered at (cx, cy).
    pub fn square(buf: &mut [u8], cx: f64, cy: f64, half: f64, intensity: u8) {
        rect(buf, cx - half, cy - half, 2.0 * half, 2.0 * half, intensity);
    }

    /// One-pixel horizontal line.
    pub fn hline(buf: &mut [u8], y: usize, intensity: u8) {
        if y < RAW {
            buf[y * RAW..(y + 1) * RAW].fill(intensity);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::draw::*;
    use super::*;

    #[test]
    fn rect_clips() {
        let mut buf = vec![0u8; RAW_FRAME];
        rect(&mut buf, -10.0, -10.0, 20.0, 20.0, 255);
        assert_eq!(buf[0], 255);
        assert_eq!(buf[9 * RAW + 9], 255);
        assert_eq!(buf[9 * RAW + 10], 0);
        assert_eq!(buf[10 * RAW], 0);
        rect(&mut buf, (RAW - 5) as f64, (RAW - 5) as f64, 99.0, 99.0, 128);
        assert_eq!(buf[RAW_FRAME - 1], 128);
    }

    #[test]
    fn clear_and_hline() {
        let mut buf = vec![0u8; RAW_FRAME];
        clear(&mut buf, 7);
        assert!(buf.iter().all(|&b| b == 7));
        hline(&mut buf, 3, 200);
        assert!(buf[3 * RAW..4 * RAW].iter().all(|&b| b == 200));
        hline(&mut buf, RAW + 5, 99); // out of range: no panic
    }
}
