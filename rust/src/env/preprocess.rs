//! DQN frame preprocessing: max-pool over consecutive raw frames and 2x
//! box downscale (168x168 -> 84x84), mirroring the Mnih et al. (2015)
//! pipeline (max over the last two emulator frames, resize, grayscale —
//! our games already render grayscale).

use super::game::{RAW, RAW_FRAME};

/// Network input resolution.
pub const NET: usize = 84;
/// Bytes in one preprocessed plane.
pub const NET_FRAME: usize = NET * NET;

/// Elementwise max of two raw frames into `a` (flicker removal).
pub fn max_pool_into(a: &mut [u8], b: &[u8]) {
    debug_assert_eq!(a.len(), RAW_FRAME);
    debug_assert_eq!(b.len(), RAW_FRAME);
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x = (*x).max(*y);
    }
}

/// 2x2 box-filter downscale RAW -> NET.
pub fn downscale(raw: &[u8], out: &mut [u8]) {
    debug_assert_eq!(raw.len(), RAW_FRAME);
    debug_assert_eq!(out.len(), NET_FRAME);
    debug_assert_eq!(RAW, 2 * NET);
    for y in 0..NET {
        let r0 = &raw[(2 * y) * RAW..(2 * y) * RAW + RAW];
        let r1 = &raw[(2 * y + 1) * RAW..(2 * y + 1) * RAW + RAW];
        let dst = &mut out[y * NET..(y + 1) * NET];
        for (x, d) in dst.iter_mut().enumerate() {
            let s = r0[2 * x] as u16 + r0[2 * x + 1] as u16 + r1[2 * x] as u16 + r1[2 * x + 1] as u16;
            *d = (s / 4) as u8;
        }
    }
}

/// DQN reward clipping: sign(r).
pub fn clip_reward(r: f64) -> f32 {
    if r > 0.0 {
        1.0
    } else if r < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_is_elementwise_max() {
        let mut a = vec![0u8; RAW_FRAME];
        let mut b = vec![0u8; RAW_FRAME];
        a[0] = 10;
        b[0] = 20;
        a[1] = 30;
        b[1] = 5;
        max_pool_into(&mut a, &b);
        assert_eq!(a[0], 20);
        assert_eq!(a[1], 30);
    }

    #[test]
    fn downscale_averages_2x2() {
        let mut raw = vec![0u8; RAW_FRAME];
        raw[0] = 100;
        raw[1] = 200;
        raw[RAW] = 60;
        raw[RAW + 1] = 40;
        let mut out = vec![0u8; NET_FRAME];
        downscale(&raw, &mut out);
        assert_eq!(out[0], 100);
        assert_eq!(out[1], 0);
    }

    #[test]
    fn downscale_constant_field() {
        let raw = vec![137u8; RAW_FRAME];
        let mut out = vec![0u8; NET_FRAME];
        downscale(&raw, &mut out);
        assert!(out.iter().all(|&v| v == 137));
    }

    #[test]
    fn clip() {
        assert_eq!(clip_reward(6.0), 1.0);
        assert_eq!(clip_reward(-0.1), -1.0);
        assert_eq!(clip_reward(0.0), 0.0);
    }
}
