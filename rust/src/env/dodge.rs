//! Dodge: obstacles rain down; survive by weaving between them.
//!
//! Actions: 0 = NOOP, 1 = LEFT, 2 = RIGHT. +1 raw reward for every
//! obstacle wave that passes the agent's row, -5 on collision (costs a
//! life). Three lives per episode, difficulty ramps with time — a reflex
//! game in the spirit of Freeway/Enduro.

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const AGENT_Y: f64 = (RAW - 14) as f64;
const AGENT_HALF: f64 = 5.0;
const OB_HALF: f64 = 6.0;
const MAX_OBS: usize = 14;

struct Obstacle {
    x: f64,
    y: f64,
    vy: f64,
    scored: bool,
}

pub struct Dodge {
    rng: Rng,
    x: f64,
    obstacles: Vec<Obstacle>,
    lives: u32,
    ticks: u32,
    spawn_cooldown: u32,
}

impl Dodge {
    pub fn new() -> Self {
        let mut d = Dodge {
            rng: Rng::new(0),
            x: RAW as f64 / 2.0,
            obstacles: Vec::new(),
            lives: 3,
            ticks: 0,
            spawn_cooldown: 0,
        };
        d.reset(0);
        d
    }

    fn difficulty(&self) -> f64 {
        1.0 + (self.ticks as f64 / 4000.0).min(1.5)
    }
}

impl Default for Dodge {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Dodge {
    fn name(&self) -> &'static str {
        "dodge"
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x444f4447); // "DODG"
        self.x = RAW as f64 / 2.0;
        self.obstacles.clear();
        self.lives = 3;
        self.ticks = 0;
        self.spawn_cooldown = 20;
    }

    fn step(&mut self, action: usize) -> StepResult {
        const SPEED: f64 = 2.6;
        match action {
            1 => self.x = (self.x - SPEED).max(AGENT_HALF),
            2 => self.x = (self.x + SPEED).min(RAW as f64 - AGENT_HALF),
            _ => {}
        }
        self.ticks += 1;

        // Spawn new obstacles.
        if self.spawn_cooldown == 0 && self.obstacles.len() < MAX_OBS {
            let x = self.rng.range_f32(OB_HALF as f32, (RAW as f64 - OB_HALF) as f32) as f64;
            let vy = (1.4 + self.rng.f64() * 1.2) * self.difficulty();
            self.obstacles.push(Obstacle { x, y: -OB_HALF, vy, scored: false });
            self.spawn_cooldown = (26.0 / self.difficulty()) as u32 + self.rng.below(10);
        } else {
            self.spawn_cooldown = self.spawn_cooldown.saturating_sub(1);
        }

        let mut reward = 0.0;
        let mut hit = false;
        for ob in &mut self.obstacles {
            ob.y += ob.vy;
            if !ob.scored && ob.y > AGENT_Y + AGENT_HALF + OB_HALF {
                ob.scored = true;
                reward += 1.0;
            }
            if (ob.x - self.x).abs() < AGENT_HALF + OB_HALF
                && (ob.y - AGENT_Y).abs() < AGENT_HALF + OB_HALF
            {
                hit = true;
            }
        }
        self.obstacles.retain(|o| o.y < RAW as f64 + OB_HALF);

        let mut done = false;
        if hit {
            reward = -5.0;
            self.lives -= 1;
            self.obstacles.clear();
            self.spawn_cooldown = 40;
            if self.lives == 0 {
                done = true;
            }
        }
        StepResult { reward, done }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 10);
        for ob in &self.obstacles {
            draw::square(buf, ob.x, ob.y, OB_HALF, 150);
        }
        draw::square(buf, self.x, AGENT_Y, AGENT_HALF, 255);
        for i in 0..self.lives {
            draw::rect(buf, 2.0 + i as f64 * 6.0, 2.0, 4.0, 4.0, 255);
        }
    }

    fn expert_action(&mut self) -> usize {
        // Repulsion from the nearest threatening obstacle.
        let mut force = 0.0;
        for ob in &self.obstacles {
            if ob.y < AGENT_Y && ob.y > AGENT_Y - 60.0 {
                let dx = self.x - ob.x;
                if dx.abs() < 2.5 * (AGENT_HALF + OB_HALF) {
                    force += (1.0 / (dx.abs() + 1.0)) * dx.signum();
                }
            }
        }
        // Mild pull back to centre.
        force += 0.002 * (RAW as f64 / 2.0 - self.x);
        if force > 0.05 {
            2
        } else if force < -0.05 {
            1
        } else {
            0
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        w.put_f64(self.x);
        w.put_usize(self.obstacles.len());
        for ob in &self.obstacles {
            w.put_f64(ob.x);
            w.put_f64(ob.y);
            w.put_f64(ob.vy);
            w.put_bool(ob.scored);
        }
        w.put_u32(self.lives);
        w.put_u32(self.ticks);
        w.put_u32(self.spawn_cooldown);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        self.x = r.f64()?;
        let n = r.usize()?;
        self.obstacles = (0..n)
            .map(|_| {
                Ok(Obstacle { x: r.f64()?, y: r.f64()?, vy: r.f64()?, scored: r.bool()? })
            })
            .collect::<anyhow::Result<_>>()?;
        self.lives = r.u32()?;
        self.ticks = r.u32()?;
        self.spawn_cooldown = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn play(expert: bool, seed: u64, cap: usize) -> f64 {
        let mut g = Dodge::new();
        g.reset(seed);
        let mut total = 0.0;
        for _ in 0..cap {
            let a = if expert { g.expert_action() } else { 0 };
            let r = g.step(a);
            total += r.reward;
            if r.done {
                break;
            }
        }
        total
    }

    #[test]
    fn noop_eventually_dies() {
        let mut g = Dodge::new();
        g.reset(1);
        let mut steps = 0;
        loop {
            steps += 1;
            if g.step(0).done {
                break;
            }
            assert!(steps < 500_000);
        }
        assert_eq!(g.lives, 0);
    }

    #[test]
    fn expert_outscores_noop() {
        let e: f64 = (0..3).map(|s| play(true, s, 8000)).sum();
        let n: f64 = (0..3).map(|s| play(false, s, 8000)).sum();
        assert!(e > n, "expert {e} vs noop {n}");
    }

    #[test]
    fn collision_clears_field() {
        let mut g = Dodge::new();
        g.reset(2);
        loop {
            let r = g.step(0);
            if r.reward < 0.0 {
                assert!(g.obstacles.is_empty());
                break;
            }
        }
    }
}
