//! Environment substrate: synthetic Atari-like games + the DQN
//! preprocessing pipeline (frame-skip, max-pool, downscale, stacking,
//! reward clipping).
//!
//! The Arcade Learning Environment is unavailable offline; these games are
//! built from scratch to exercise the identical code path — per-step CPU
//! simulation + rendering + preprocessing feeding 84x84x4 uint8 stacks into
//! the network (rust/DESIGN.md §3 documents the substitution).
//!
//! [`vec::VecEnv`] packs B environments per sampler thread so the
//! coordinator can run W×B streams (rust/DESIGN.md §5).

pub mod atari;
pub mod breakout;
pub mod chase;
pub mod dodge;
pub mod game;
pub mod harvest;
pub mod pong;
pub mod preprocess;
pub mod registry;
pub mod seeker;
pub mod vec;

pub use atari::{make_env, AtariEnv, EnvStep, STACK, STATE_BYTES};
pub use game::{Game, StepResult, RAW, RAW_FRAME};
pub use preprocess::{NET, NET_FRAME};
pub use registry::{make_game, GAMES};
pub use vec::VecEnv;
