//! Game registry: name -> constructor, plus the standard evaluation suite.

use anyhow::{bail, Result};

use super::breakout::Breakout;
use super::chase::Chase;
use super::dodge::Dodge;
use super::game::Game;
use super::harvest::Harvest;
use super::pong::Pong;
use super::seeker::Seeker;

/// All registered games (the Table 4 suite).
pub const GAMES: &[&str] = &["pong", "breakout", "seeker", "dodge", "chase", "harvest"];

/// Construct a game by name.
pub fn make_game(name: &str) -> Result<Box<dyn Game>> {
    Ok(match name {
        "pong" => Box::new(Pong::new()),
        "breakout" => Box::new(Breakout::new()),
        "seeker" => Box::new(Seeker::new()),
        "dodge" => Box::new(Dodge::new()),
        "chase" => Box::new(Chase::new()),
        "harvest" => Box::new(Harvest::new()),
        other => bail!("unknown game {other:?}; available: {GAMES:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::game::RAW_FRAME;

    #[test]
    fn all_games_construct_step_render() {
        for name in GAMES {
            let mut g = make_game(name).unwrap();
            assert_eq!(g.name(), *name);
            assert!(g.num_actions() >= 2 && g.num_actions() <= 6, "{name}");
            g.reset(1);
            let mut buf = vec![0u8; RAW_FRAME];
            for i in 0..100 {
                let a = i % g.num_actions();
                g.step(a);
            }
            g.render(&mut buf);
            assert!(buf.iter().any(|&b| b > 0), "{name} renders something");
            // Expert policy always returns a legal action.
            for _ in 0..50 {
                let a = g.expert_action();
                assert!(a < g.num_actions(), "{name} expert action {a}");
                g.step(a);
            }
        }
    }

    /// Every game's save/load must be field-complete: snapshot mid-episode,
    /// restore into a replica that was driven to a *different* state, then
    /// verify hundreds of continued steps (rewards, dones, renders — which
    /// exercise every field — and further RNG draws) match exactly.
    #[test]
    fn all_games_snapshot_roundtrip_mid_episode() {
        use crate::ckpt::{ByteReader, ByteWriter};
        for name in GAMES {
            let mut a = make_game(name).unwrap();
            a.reset(5);
            for i in 0..257 {
                a.step(i % a.num_actions());
            }
            let mut w = ByteWriter::new();
            a.save_state(&mut w);
            let bytes = w.into_bytes();

            let mut b = make_game(name).unwrap();
            b.reset(99); // deliberately different pre-restore state
            for _ in 0..31 {
                b.step(1 % b.num_actions());
            }
            let mut r = ByteReader::new(&bytes);
            b.load_state(&mut r).unwrap();
            assert_eq!(r.remaining(), 0, "{name}: loader left bytes unread");

            let mut buf_a = vec![0u8; RAW_FRAME];
            let mut buf_b = vec![0u8; RAW_FRAME];
            for i in 0..400 {
                let action = (i * 7) % a.num_actions();
                let ra = a.step(action);
                let rb = b.step(action);
                assert_eq!(ra, rb, "{name}: step {i} diverged after restore");
                if i % 97 == 0 {
                    a.render(&mut buf_a);
                    b.render(&mut buf_b);
                    assert_eq!(buf_a, buf_b, "{name}: render diverged at step {i}");
                }
            }
        }
    }

    #[test]
    fn unknown_game_lists_available() {
        let err = match make_game("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("pong"), "{err}");
    }
}
