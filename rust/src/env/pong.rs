//! Pong-like game: ball + two paddles, scripted opponent.
//!
//! Actions: 0 = NOOP, 1 = UP, 2 = DOWN. Reward +1 when the opponent misses,
//! -1 when the agent misses; episode ends when either side reaches 21
//! points (matching Atari Pong's scoring shape). The paper uses Pong for
//! its §5.1 speed tests, noting the choice of game is timing-irrelevant.

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const PADDLE_H: f64 = 22.0;
const PADDLE_W: f64 = 4.0;
const AGENT_X: f64 = (RAW - 8) as f64;
const OPP_X: f64 = 4.0;
const BALL: f64 = 3.0;
const WIN_SCORE: u32 = 21;

pub struct Pong {
    rng: Rng,
    ball_x: f64,
    ball_y: f64,
    vel_x: f64,
    vel_y: f64,
    agent_y: f64,
    opp_y: f64,
    agent_score: u32,
    opp_score: u32,
    /// Scripted-opponent tracking speed; < ball speed so it is beatable.
    opp_speed: f64,
}

impl Pong {
    pub fn new() -> Self {
        let mut p = Pong {
            rng: Rng::new(0),
            ball_x: 0.0,
            ball_y: 0.0,
            vel_x: 0.0,
            vel_y: 0.0,
            agent_y: RAW as f64 / 2.0,
            opp_y: RAW as f64 / 2.0,
            agent_score: 0,
            opp_score: 0,
            opp_speed: 1.35,
        };
        p.serve(true);
        p
    }

    fn serve(&mut self, toward_agent: bool) {
        self.ball_x = RAW as f64 / 2.0;
        self.ball_y = self.rng.range_f32(30.0, (RAW - 30) as f32) as f64;
        let speed = 2.4;
        let angle = self.rng.range_f32(-0.6, 0.6) as f64;
        let dir = if toward_agent { 1.0 } else { -1.0 };
        self.vel_x = dir * speed * angle.cos();
        self.vel_y = speed * angle.sin();
    }
}

impl Default for Pong {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Pong {
    fn name(&self) -> &'static str {
        "pong"
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x504f4e47); // "PONG"
        self.agent_y = RAW as f64 / 2.0;
        self.opp_y = RAW as f64 / 2.0;
        self.agent_score = 0;
        self.opp_score = 0;
        let toward_agent = self.rng.chance(0.5);
        self.serve(toward_agent);
    }

    fn step(&mut self, action: usize) -> StepResult {
        const PSPEED: f64 = 2.2;
        match action {
            1 => self.agent_y = (self.agent_y - PSPEED).max(PADDLE_H / 2.0),
            2 => self.agent_y = (self.agent_y + PSPEED).min(RAW as f64 - PADDLE_H / 2.0),
            _ => {}
        }
        // Scripted opponent: track the ball with bounded speed + jitter.
        let target = self.ball_y + self.rng.range_f32(-6.0, 6.0) as f64;
        let dy = (target - self.opp_y).clamp(-self.opp_speed, self.opp_speed);
        self.opp_y = (self.opp_y + dy).clamp(PADDLE_H / 2.0, RAW as f64 - PADDLE_H / 2.0);

        self.ball_x += self.vel_x;
        self.ball_y += self.vel_y;

        // Wall bounces.
        if self.ball_y < BALL {
            self.ball_y = BALL;
            self.vel_y = self.vel_y.abs();
        }
        if self.ball_y > RAW as f64 - BALL {
            self.ball_y = RAW as f64 - BALL;
            self.vel_y = -self.vel_y.abs();
        }

        let mut reward = 0.0;
        // Agent paddle.
        if self.ball_x >= AGENT_X - BALL && self.vel_x > 0.0 {
            if (self.ball_y - self.agent_y).abs() < PADDLE_H / 2.0 + BALL {
                self.vel_x = -self.vel_x.abs();
                // Impart spin based on contact point.
                self.vel_y += 0.25 * (self.ball_y - self.agent_y) / (PADDLE_H / 2.0);
            } else if self.ball_x > RAW as f64 {
                self.opp_score += 1;
                reward = -1.0;
                self.serve(false);
            }
        }
        // Opponent paddle.
        if self.ball_x <= OPP_X + PADDLE_W + BALL && self.vel_x < 0.0 {
            if (self.ball_y - self.opp_y).abs() < PADDLE_H / 2.0 + BALL {
                self.vel_x = self.vel_x.abs();
                self.vel_y += 0.25 * (self.ball_y - self.opp_y) / (PADDLE_H / 2.0);
            } else if self.ball_x < 0.0 {
                self.agent_score += 1;
                reward = 1.0;
                self.serve(true);
            }
        }

        let done = self.agent_score >= WIN_SCORE || self.opp_score >= WIN_SCORE;
        StepResult { reward, done }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 20);
        draw::hline(buf, 0, 90);
        draw::hline(buf, RAW - 1, 90);
        draw::rect(buf, OPP_X, self.opp_y - PADDLE_H / 2.0, PADDLE_W, PADDLE_H, 140);
        draw::rect(buf, AGENT_X, self.agent_y - PADDLE_H / 2.0, PADDLE_W, PADDLE_H, 255);
        draw::square(buf, self.ball_x, self.ball_y, BALL, 230);
    }

    fn expert_action(&mut self) -> usize {
        // Track the ball when it approaches; recentre otherwise.
        let target = if self.vel_x > 0.0 { self.ball_y } else { RAW as f64 / 2.0 };
        if target < self.agent_y - 3.0 {
            1
        } else if target > self.agent_y + 3.0 {
            2
        } else {
            0
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        w.put_f64(self.ball_x);
        w.put_f64(self.ball_y);
        w.put_f64(self.vel_x);
        w.put_f64(self.vel_y);
        w.put_f64(self.agent_y);
        w.put_f64(self.opp_y);
        w.put_u32(self.agent_score);
        w.put_u32(self.opp_score);
        w.put_f64(self.opp_speed);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        self.ball_x = r.f64()?;
        self.ball_y = r.f64()?;
        self.vel_x = r.f64()?;
        self.vel_y = r.f64()?;
        self.agent_y = r.f64()?;
        self.opp_y = r.f64()?;
        self.agent_score = r.u32()?;
        self.opp_score = r.u32()?;
        self.opp_speed = r.f64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::game::RAW_FRAME;

    #[test]
    fn episode_terminates() {
        let mut g = Pong::new();
        g.reset(1);
        let mut steps = 0;
        let mut total = 0.0;
        loop {
            let r = g.step(0); // NOOP agent loses every rally
            total += r.reward;
            steps += 1;
            if r.done {
                break;
            }
            assert!(steps < 200_000, "episode must terminate");
        }
        assert!(total <= -(WIN_SCORE as f64) + 21.0);
        assert!((total as i64) <= 0, "noop agent cannot win: {total}");
    }

    #[test]
    fn expert_beats_noop() {
        let score = |expert: bool| {
            let mut g = Pong::new();
            g.reset(7);
            let mut total = 0.0;
            for _ in 0..20_000 {
                let a = if expert { g.expert_action() } else { 0 };
                let r = g.step(a);
                total += r.reward;
                if r.done {
                    break;
                }
            }
            total
        };
        assert!(score(true) > score(false) + 5.0);
    }

    #[test]
    fn render_shows_objects() {
        let mut g = Pong::new();
        g.reset(3);
        let mut buf = vec![0u8; RAW_FRAME];
        g.render(&mut buf);
        assert!(buf.iter().any(|&b| b == 255), "agent paddle visible");
        assert!(buf.iter().any(|&b| b == 230), "ball visible");
        assert!(buf.iter().any(|&b| b == 140), "opponent visible");
    }

    #[test]
    fn reset_is_deterministic() {
        let run = |seed| {
            let mut g = Pong::new();
            g.reset(seed);
            let mut buf = vec![0u8; RAW_FRAME];
            for _ in 0..50 {
                g.step(1);
            }
            g.render(&mut buf);
            buf
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
