//! `AtariEnv`: the full agent-facing environment wrapper.
//!
//! Wraps a raw [`Game`] with the standard DQN pipeline:
//! * action repeat (frame-skip) with reward accumulation,
//! * max-pool over the final two raw frames (flicker removal),
//! * 2x box downscale to 84x84,
//! * 4-frame history stacking (channel-last, oldest..newest),
//! * reward clipping to {-1, 0, +1},
//! * episode step cap (27k agent steps = ALE's 108k-frame cap / skip 4).
//!
//! This wrapper is the CPU-cost unit the paper's scheduling is built
//! around: one `step()` = simulate `skip` ticks + render + preprocess.

use anyhow::Result;

use super::game::{Game, RAW_FRAME};
use super::preprocess::{clip_reward, downscale, max_pool_into, NET_FRAME};

/// Stacked-state geometry (must match the artifact manifest's frame shape).
pub const STACK: usize = 4;
pub const STATE_BYTES: usize = NET_FRAME * STACK;

/// Outcome of one agent-level step.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnvStep {
    /// Clipped reward (what the learner sees).
    pub reward: f32,
    /// Un-clipped game reward (what evaluation reports).
    pub raw_reward: f64,
    /// Episode ended this step (terminal or step cap).
    pub done: bool,
}

pub struct AtariEnv {
    game: Box<dyn Game>,
    skip: usize,
    max_steps: usize,
    raw_a: Vec<u8>,
    raw_b: Vec<u8>,
    /// 4 preprocessed planes, ring-indexed by `head` (head = newest).
    planes: [Vec<u8>; STACK],
    head: usize,
    steps_this_episode: usize,
    episode_raw_return: f64,
    episodes_completed: u64,
    seed: u64,
    episode_index: u64,
}

impl AtariEnv {
    pub fn new(game: Box<dyn Game>, seed: u64) -> Self {
        let mut env = AtariEnv {
            game,
            skip: 4,
            max_steps: 27_000,
            raw_a: vec![0; RAW_FRAME],
            raw_b: vec![0; RAW_FRAME],
            planes: [
                vec![0; NET_FRAME],
                vec![0; NET_FRAME],
                vec![0; NET_FRAME],
                vec![0; NET_FRAME],
            ],
            head: 0,
            steps_this_episode: 0,
            episode_raw_return: 0.0,
            episodes_completed: 0,
            seed,
            episode_index: 0,
        };
        env.reset();
        env
    }

    pub fn with_skip(mut self, skip: usize) -> Self {
        assert!(skip >= 1);
        self.skip = skip;
        self
    }

    pub fn with_max_steps(mut self, max_steps: usize) -> Self {
        self.max_steps = max_steps;
        self
    }

    pub fn game_name(&self) -> &'static str {
        self.game.name()
    }

    pub fn num_actions(&self) -> usize {
        self.game.num_actions()
    }

    /// Begin a fresh episode (new deterministic sub-seed each time).
    pub fn reset(&mut self) {
        self.game.reset(self.seed.wrapping_add(self.episode_index.wrapping_mul(0x9E37)));
        self.episode_index += 1;
        self.steps_this_episode = 0;
        self.episode_raw_return = 0.0;
        // Fill the whole history with the initial frame.
        self.game.render(&mut self.raw_a);
        let mut plane = vec![0u8; NET_FRAME];
        downscale(&self.raw_a, &mut plane);
        for p in &mut self.planes {
            p.copy_from_slice(&plane);
        }
        self.head = STACK - 1;
    }

    /// One agent-level step: repeat `action` for `skip` raw ticks.
    pub fn step(&mut self, action: usize) -> EnvStep {
        debug_assert!(action < self.game.num_actions());
        let mut raw_reward = 0.0;
        let mut done = false;
        for k in 0..self.skip {
            let r = self.game.step(action);
            raw_reward += r.reward;
            // Render only the ticks that feed the max-pool (last two).
            if k == self.skip.saturating_sub(2) {
                self.game.render(&mut self.raw_a);
            } else if k == self.skip - 1 {
                self.game.render(&mut self.raw_b);
            }
            if r.done {
                done = true;
                // Terminal frame still enters the stack below.
                if k < self.skip.saturating_sub(2) {
                    self.game.render(&mut self.raw_a);
                }
                self.game.render(&mut self.raw_b);
                break;
            }
        }
        if self.skip >= 2 {
            max_pool_into(&mut self.raw_a, &self.raw_b);
        } else {
            self.game.render(&mut self.raw_a);
        }

        self.head = (self.head + 1) % STACK;
        downscale(&self.raw_a, &mut self.planes[self.head]);

        self.steps_this_episode += 1;
        self.episode_raw_return += raw_reward;
        if self.steps_this_episode >= self.max_steps {
            done = true;
        }
        if done {
            self.episodes_completed += 1;
        }
        EnvStep { reward: clip_reward(raw_reward), raw_reward, done }
    }

    /// Newest preprocessed plane (what the replay memory stores).
    pub fn latest_plane(&self) -> &[u8] {
        &self.planes[self.head]
    }

    /// Write the stacked state `[84, 84, 4]` channel-last into `out`
    /// (channel 0 = oldest frame, channel 3 = newest).
    pub fn write_state(&self, out: &mut [u8]) {
        assert_eq!(out.len(), STATE_BYTES);
        let oldest = (self.head + 1) % STACK;
        for c in 0..STACK {
            let plane = &self.planes[(oldest + c) % STACK];
            for i in 0..NET_FRAME {
                out[i * STACK + c] = plane[i];
            }
        }
    }

    pub fn state_vec(&self) -> Vec<u8> {
        let mut v = vec![0u8; STATE_BYTES];
        self.write_state(&mut v);
        v
    }

    pub fn episode_raw_return(&self) -> f64 {
        self.episode_raw_return
    }

    pub fn episodes_completed(&self) -> u64 {
        self.episodes_completed
    }

    /// Scripted expert action (human-proxy anchor for Table 4).
    pub fn expert_action(&mut self) -> usize {
        self.game.expert_action()
    }
}

/// Checkpoint the full wrapper state: the frame-stack ring, episode
/// bookkeeping, reseed counters, and the wrapped game's simulator state.
/// The raw render scratch buffers are rebuilt on the next step, so they are
/// not part of the state.
impl crate::ckpt::Snapshot for AtariEnv {
    fn kind(&self) -> &'static str {
        "atari_env"
    }

    fn save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_str(self.game.name());
        w.put_usize(self.skip);
        w.put_usize(self.max_steps);
        for plane in &self.planes {
            w.put_bytes(plane);
        }
        w.put_usize(self.head);
        w.put_usize(self.steps_this_episode);
        w.put_f64(self.episode_raw_return);
        w.put_u64(self.episodes_completed);
        w.put_u64(self.seed);
        w.put_u64(self.episode_index);
        self.game.save_state(w);
    }

    fn load(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        let name = r.str()?;
        if name != self.game.name() {
            anyhow::bail!(
                "checkpoint env is {name:?}, this machine runs {:?}",
                self.game.name()
            );
        }
        self.skip = r.usize()?;
        self.max_steps = r.usize()?;
        for plane in &mut self.planes {
            let bytes = r.bytes()?;
            if bytes.len() != NET_FRAME {
                anyhow::bail!("checkpoint plane has {} bytes, want {NET_FRAME}", bytes.len());
            }
            plane.copy_from_slice(bytes);
        }
        self.head = r.usize()?;
        if self.head >= STACK {
            anyhow::bail!("checkpoint frame-stack head {} out of range", self.head);
        }
        self.steps_this_episode = r.usize()?;
        self.episode_raw_return = r.f64()?;
        self.episodes_completed = r.u64()?;
        self.seed = r.u64()?;
        self.episode_index = r.u64()?;
        self.game.load_state(r)
    }
}

/// Construct the env for a registered game name.
pub fn make_env(game: &str, seed: u64) -> Result<AtariEnv> {
    Ok(AtariEnv::new(super::registry::make_game(game)?, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::registry::make_game;

    #[test]
    fn state_shape_and_stacking() {
        let mut env = AtariEnv::new(make_game("pong").unwrap(), 1);
        let s0 = env.state_vec();
        assert_eq!(s0.len(), STATE_BYTES);
        // After reset all 4 channels are the same frame.
        for i in 0..NET_FRAME {
            let base = s0[i * STACK];
            for c in 1..STACK {
                assert_eq!(s0[i * STACK + c], base);
            }
        }
        // After one step, channel 3 is the newest plane.
        env.step(1);
        let s1 = env.state_vec();
        let newest = env.latest_plane();
        for i in (0..NET_FRAME).step_by(97) {
            assert_eq!(s1[i * STACK + 3], newest[i]);
        }
        // Old newest became channel 2.
        for i in (0..NET_FRAME).step_by(97) {
            assert_eq!(s1[i * STACK + 2], s0[i * STACK + 3]);
        }
    }

    #[test]
    fn rewards_are_clipped() {
        let mut env = AtariEnv::new(make_game("chase").unwrap(), 2);
        // Chase emits +-10 raw; the clipped channel must stay in {-1,0,1}.
        for _ in 0..2_000 {
            let r = env.step(4);
            assert!([-1.0, 0.0, 1.0].contains(&r.reward));
            if r.done {
                env.reset();
            }
        }
    }

    #[test]
    fn step_cap_terminates() {
        let mut env = AtariEnv::new(make_game("seeker").unwrap(), 3).with_max_steps(10);
        let mut done = false;
        for _ in 0..10 {
            done = env.step(0).done;
        }
        assert!(done);
    }

    #[test]
    fn episodes_auto_reseed() {
        let mut env = AtariEnv::new(make_game("pong").unwrap(), 4).with_max_steps(5);
        for _ in 0..5 {
            env.step(0);
        }
        let first = env.state_vec();
        env.reset();
        for _ in 0..5 {
            env.step(0);
        }
        let second = env.state_vec();
        assert_ne!(first, second, "new episode must differ (new sub-seed)");
    }

    /// Full wrapper snapshot: frame stack, episode counters, and reseed
    /// state survive a save/load — continued steps, states, returns, and
    /// the per-episode reseed sequence are identical.
    #[test]
    fn atari_env_snapshot_roundtrip() {
        use crate::ckpt::{ByteReader, ByteWriter, Snapshot};
        let mut a = AtariEnv::new(make_game("breakout").unwrap(), 17).with_max_steps(40);
        for i in 0..97 {
            if a.step(i % 4).done {
                a.reset();
            }
        }
        let mut w = ByteWriter::new();
        a.save(&mut w);
        let bytes = w.into_bytes();

        let mut b = AtariEnv::new(make_game("breakout").unwrap(), 1);
        b.step(1);
        let mut r = ByteReader::new(&bytes);
        b.load(&mut r).unwrap();
        r.finish().unwrap();

        assert_eq!(a.state_vec(), b.state_vec(), "restored frame stack differs");
        assert_eq!(a.episode_raw_return(), b.episode_raw_return());
        for i in 0..300 {
            let ra = a.step(i % 4);
            let rb = b.step(i % 4);
            assert_eq!(ra.reward, rb.reward, "step {i}");
            assert_eq!(ra.raw_reward, rb.raw_reward, "step {i}");
            assert_eq!(ra.done, rb.done, "step {i}");
            if ra.done {
                // The reseed counter must also have been restored: fresh
                // episodes draw the same sub-seeds on both replicas.
                a.reset();
                b.reset();
                assert_eq!(a.state_vec(), b.state_vec(), "post-reset state differs");
            }
        }
        assert_eq!(a.state_vec(), b.state_vec());
        assert_eq!(a.episodes_completed(), b.episodes_completed());

        // A checkpoint from a different game must be refused.
        let mut other = AtariEnv::new(make_game("pong").unwrap(), 3);
        let mut r = ByteReader::new(&bytes);
        let err = other.load(&mut r).unwrap_err().to_string();
        assert!(err.contains("breakout"), "{err}");
    }

    #[test]
    fn deterministic_given_seed_and_actions() {
        let run = || {
            let mut env = AtariEnv::new(make_game("breakout").unwrap(), 9);
            let mut rewards = Vec::new();
            for i in 0..200 {
                let r = env.step(i % 4);
                rewards.push((r.reward, r.done));
                if r.done {
                    env.reset();
                }
            }
            (rewards, env.state_vec())
        };
        assert_eq!(run(), run());
    }
}
