//! Harvest: a delayed-gratification farming grid.
//!
//! Actions: 0 = NOOP, 1 = UP, 2 = DOWN, 3 = LEFT, 4 = RIGHT, 5 = INTERACT.
//! INTERACT on an empty plot plants a seed; the plot ripens after a growth
//! delay; INTERACT on a ripe plot harvests it for +5 raw reward. Planting
//! costs nothing but pays off only ~200 ticks later — a long-horizon credit
//! assignment probe (the Frostbite/H.E.R.O. role in the suite).

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const GRID: usize = 6;
const CELL: f64 = RAW as f64 / GRID as f64;
const GROWTH_TICKS: u32 = 200;
const EPISODE_TICKS: u32 = 4000;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Plot {
    Empty,
    Growing(u32),
    Ripe,
}

pub struct Harvest {
    rng: Rng,
    col: usize,
    row: usize,
    plots: [[Plot; GRID]; GRID],
    ticks: u32,
}

impl Harvest {
    pub fn new() -> Self {
        let mut h = Harvest {
            rng: Rng::new(0),
            col: 0,
            row: 0,
            plots: [[Plot::Empty; GRID]; GRID],
            ticks: 0,
        };
        h.reset(0);
        h
    }
}

impl Default for Harvest {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Harvest {
    fn name(&self) -> &'static str {
        "harvest"
    }

    fn num_actions(&self) -> usize {
        6
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x48525654); // "HRVT"
        self.col = GRID / 2;
        self.row = GRID / 2;
        self.plots = [[Plot::Empty; GRID]; GRID];
        // A few pre-grown plots so reward is reachable early.
        for _ in 0..4 {
            let c = self.rng.below_usize(GRID);
            let r = self.rng.below_usize(GRID);
            self.plots[r][c] = Plot::Ripe;
        }
        self.ticks = 0;
    }

    fn step(&mut self, action: usize) -> StepResult {
        let mut reward = 0.0;
        match action {
            1 if self.row > 0 => self.row -= 1,
            2 if self.row < GRID - 1 => self.row += 1,
            3 if self.col > 0 => self.col -= 1,
            4 if self.col < GRID - 1 => self.col += 1,
            5 => match self.plots[self.row][self.col] {
                Plot::Empty => self.plots[self.row][self.col] = Plot::Growing(GROWTH_TICKS),
                Plot::Ripe => {
                    reward += 5.0;
                    self.plots[self.row][self.col] = Plot::Empty;
                }
                Plot::Growing(_) => {}
            },
            _ => {}
        }
        // Advance growth.
        for row in &mut self.plots {
            for plot in row {
                if let Plot::Growing(t) = plot {
                    *t = t.saturating_sub(1);
                    if *t == 0 {
                        *plot = Plot::Ripe;
                    }
                }
            }
        }
        self.ticks += 1;
        StepResult { reward, done: self.ticks >= EPISODE_TICKS }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 18);
        for (r, row) in self.plots.iter().enumerate() {
            for (c, plot) in row.iter().enumerate() {
                let shade = match plot {
                    Plot::Empty => 40,
                    Plot::Growing(t) => 90 + (70 * (GROWTH_TICKS - t) / GROWTH_TICKS) as u8,
                    Plot::Ripe => 210,
                };
                draw::rect(
                    buf,
                    c as f64 * CELL + 2.0,
                    r as f64 * CELL + 2.0,
                    CELL - 4.0,
                    CELL - 4.0,
                    shade,
                );
            }
        }
        draw::square(
            buf,
            self.col as f64 * CELL + CELL / 2.0,
            self.row as f64 * CELL + CELL / 2.0,
            5.0,
            255,
        );
    }

    fn expert_action(&mut self) -> usize {
        // Harvest ripe plots; keep planting density high: interact whenever
        // standing on something actionable (ripe -> harvest, empty -> plant).
        if matches!(self.plots[self.row][self.col], Plot::Ripe | Plot::Empty) {
            return 5;
        }
        // Nearest ripe plot.
        let mut best: Option<(usize, usize, usize)> = None;
        for r in 0..GRID {
            for c in 0..GRID {
                if self.plots[r][c] == Plot::Ripe {
                    let d = r.abs_diff(self.row) + c.abs_diff(self.col);
                    if best.map(|(bd, _, _)| d < bd).unwrap_or(true) {
                        best = Some((d, r, c));
                    }
                }
            }
        }
        match best {
            Some((_, r, c)) => {
                if r < self.row {
                    1
                } else if r > self.row {
                    2
                } else if c < self.col {
                    3
                } else {
                    4
                }
            }
            None => 1 + self.rng.below_usize(4), // wander to the next plot
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        w.put_usize(self.col);
        w.put_usize(self.row);
        for row in &self.plots {
            for plot in row {
                match plot {
                    Plot::Empty => w.put_u32(u32::MAX),
                    Plot::Growing(t) => w.put_u32(*t),
                    Plot::Ripe => w.put_u32(0),
                }
            }
        }
        w.put_u32(self.ticks);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        self.col = r.usize()?;
        self.row = r.usize()?;
        for row in &mut self.plots {
            for plot in row {
                *plot = match r.u32()? {
                    u32::MAX => Plot::Empty,
                    0 => Plot::Ripe,
                    t => Plot::Growing(t),
                };
            }
        }
        self.ticks = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planting_ripens_after_delay() {
        let mut g = Harvest::new();
        g.reset(1);
        g.plots = [[Plot::Empty; GRID]; GRID];
        g.step(5); // plant
        assert!(matches!(g.plots[g.row][g.col], Plot::Growing(_)));
        for _ in 0..GROWTH_TICKS {
            g.step(0);
        }
        assert_eq!(g.plots[g.row][g.col], Plot::Ripe);
        let r = g.step(5);
        assert_eq!(r.reward, 5.0);
        assert_eq!(g.plots[g.row][g.col], Plot::Empty);
    }

    #[test]
    fn expert_harvests() {
        let mut g = Harvest::new();
        g.reset(2);
        let mut total = 0.0;
        loop {
            let a = g.expert_action();
            let r = g.step(a);
            total += r.reward;
            if r.done {
                break;
            }
        }
        assert!(total >= 20.0, "expert harvested only {total}");
    }

    #[test]
    fn movement_respects_bounds() {
        let mut g = Harvest::new();
        g.reset(3);
        for _ in 0..100 {
            g.step(1);
        }
        assert_eq!(g.row, 0);
        for _ in 0..100 {
            g.step(3);
        }
        assert_eq!(g.col, 0);
    }
}
