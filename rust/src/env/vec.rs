//! `VecEnv`: B environment instances stepped as one batch.
//!
//! The paper's Synchronized Execution batches W size-1 inferences into one
//! accelerator transaction, but each sampler thread still drives exactly
//! one environment — throughput is capped by thread count. `VecEnv` is the
//! missing axis (CuLE / Stooke & Abbeel style): each sampler thread owns B
//! independent environments, steps them back-to-back, and exposes their
//! stacked states as ONE contiguous `B * STATE_BYTES` buffer so batched
//! inference reads the sampler's states without any gather copy. The
//! coordinator then runs W×B streams and one device transaction serves
//! W×B environment steps in synchronized modes (rust/DESIGN.md §5).
//!
//! Envs keep fully independent seeds and episode lifecycles; `VecEnv` adds
//! no randomness of its own, so B=1 behaves exactly like a bare
//! [`AtariEnv`].

use anyhow::Result;

use super::atari::{make_env, AtariEnv, EnvStep, STATE_BYTES};

pub struct VecEnv {
    envs: Vec<AtariEnv>,
}

impl VecEnv {
    /// One environment per seed, all running `game`.
    pub fn new(game: &str, seeds: &[u64]) -> Result<VecEnv> {
        let mut envs = Vec::with_capacity(seeds.len());
        for &seed in seeds {
            envs.push(make_env(game, seed)?);
        }
        Ok(VecEnv { envs })
    }

    /// Number of environments (B).
    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    pub fn num_actions(&self) -> usize {
        self.envs[0].num_actions()
    }

    pub fn env(&self, j: usize) -> &AtariEnv {
        &self.envs[j]
    }

    pub fn env_mut(&mut self, j: usize) -> &mut AtariEnv {
        &mut self.envs[j]
    }

    /// Step environment `j`.
    pub fn step(&mut self, j: usize, action: usize) -> EnvStep {
        self.envs[j].step(action)
    }

    /// Step every environment with its own action (throughput benches; the
    /// coordinator's sampler loop interleaves bookkeeping and uses
    /// [`VecEnv::step`] directly).
    pub fn step_batch(&mut self, actions: &[usize], out: &mut Vec<EnvStep>) {
        debug_assert_eq!(actions.len(), self.envs.len());
        out.clear();
        for (env, &a) in self.envs.iter_mut().zip(actions.iter()) {
            out.push(env.step(a));
        }
    }

    pub fn reset(&mut self, j: usize) {
        self.envs[j].reset();
    }

    /// Write all B stacked states into `out` as contiguous `STATE_BYTES`
    /// blocks — the zero-copy input of one batched inference.
    pub fn write_states(&self, out: &mut [u8]) {
        assert_eq!(out.len(), self.envs.len() * STATE_BYTES);
        for (j, env) in self.envs.iter().enumerate() {
            env.write_state(&mut out[j * STATE_BYTES..(j + 1) * STATE_BYTES]);
        }
    }

    /// Write environment `j`'s stacked state into `out`.
    pub fn write_state(&self, j: usize, out: &mut [u8]) {
        self.envs[j].write_state(out);
    }

    /// Newest preprocessed plane of environment `j` (what replay stores).
    pub fn latest_plane(&self, j: usize) -> &[u8] {
        self.envs[j].latest_plane()
    }

    /// Checkpoint all B environments (in stream order).
    pub fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        use crate::ckpt::Snapshot;
        w.put_usize(self.envs.len());
        for env in &self.envs {
            env.save(w);
        }
    }

    /// Restore all B environments from [`VecEnv::save_state`].
    pub fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        use crate::ckpt::Snapshot;
        let n = r.usize()?;
        if n != self.envs.len() {
            anyhow::bail!("checkpoint has {n} env streams, this context has {}", self.envs.len());
        }
        for env in &mut self.envs {
            env.load(r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_states_match_per_env_states() {
        let v = VecEnv::new("seeker", &[1, 2, 3]).unwrap();
        let mut all = vec![0u8; 3 * STATE_BYTES];
        v.write_states(&mut all);
        for j in 0..3 {
            let mut one = vec![0u8; STATE_BYTES];
            v.write_state(j, &mut one);
            assert_eq!(&all[j * STATE_BYTES..(j + 1) * STATE_BYTES], &one[..]);
        }
    }

    #[test]
    fn envs_are_independent_streams() {
        let mut v = VecEnv::new("pong", &[10, 20]).unwrap();
        for _ in 0..5 {
            v.step(0, 2);
            v.step(1, 2);
        }
        let mut a = vec![0u8; STATE_BYTES];
        let mut b = vec![0u8; STATE_BYTES];
        v.write_state(0, &mut a);
        v.write_state(1, &mut b);
        assert_ne!(a, b, "different seeds must diverge");
    }

    #[test]
    fn single_env_matches_bare_atari_env() {
        // B=1 must be byte-identical to driving AtariEnv directly.
        let mut v = VecEnv::new("breakout", &[9]).unwrap();
        let mut bare = make_env("breakout", 9).unwrap();
        for i in 0..50 {
            let rv = v.step(0, i % 4);
            let rb = bare.step(i % 4);
            assert_eq!(rv.reward, rb.reward);
            assert_eq!(rv.done, rb.done);
            if rv.done {
                v.reset(0);
                bare.reset();
            }
        }
        let mut sv = vec![0u8; STATE_BYTES];
        v.write_state(0, &mut sv);
        let mut sb = vec![0u8; STATE_BYTES];
        bare.write_state(&mut sb);
        assert_eq!(sv, sb);
    }

    #[test]
    fn step_batch_steps_all() {
        let mut v = VecEnv::new("seeker", &[1, 2, 3, 4]).unwrap();
        let mut out = Vec::new();
        v.step_batch(&[0, 1, 2, 3], &mut out);
        assert_eq!(out.len(), 4);
    }
}
