//! Chase: catch a fleeing target while an enemy pursues you.
//!
//! Actions: 0 = NOOP, 1 = UP, 2 = DOWN, 3 = LEFT, 4 = RIGHT.
//! +10 raw for each catch (target respawns), -10 when the enemy tags you
//! (costs one of 3 lives). Mixes approach and avoidance pressure, like
//! the ghost dynamics the paper's hard-exploration discussion references.

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const HALF: f64 = 4.5;
const EPISODE_TICKS: u32 = 4000;

pub struct Chase {
    rng: Rng,
    x: f64,
    y: f64,
    tx: f64,
    ty: f64,
    ex: f64,
    ey: f64,
    lives: u32,
    ticks: u32,
}

impl Chase {
    pub fn new() -> Self {
        let mut c = Chase {
            rng: Rng::new(0),
            x: 0.0,
            y: 0.0,
            tx: 0.0,
            ty: 0.0,
            ex: 0.0,
            ey: 0.0,
            lives: 3,
            ticks: 0,
        };
        c.reset(0);
        c
    }

    fn respawn_target(&mut self) {
        // Spawn away from the agent.
        loop {
            self.tx = self.rng.range_f32(15.0, (RAW - 15) as f32) as f64;
            self.ty = self.rng.range_f32(15.0, (RAW - 15) as f32) as f64;
            if (self.tx - self.x).hypot(self.ty - self.y) > 50.0 {
                break;
            }
        }
    }
}

impl Default for Chase {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Chase {
    fn name(&self) -> &'static str {
        "chase"
    }

    fn num_actions(&self) -> usize {
        5
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x43485345); // "CHSE"
        self.x = RAW as f64 / 2.0;
        self.y = RAW as f64 / 2.0;
        self.ex = 10.0;
        self.ey = 10.0;
        self.lives = 3;
        self.ticks = 0;
        self.respawn_target();
    }

    fn step(&mut self, action: usize) -> StepResult {
        const SPEED: f64 = 2.4;
        const TSPEED: f64 = 1.7;
        const ESPEED: f64 = 1.5;
        match action {
            1 => self.y -= SPEED,
            2 => self.y += SPEED,
            3 => self.x -= SPEED,
            4 => self.x += SPEED,
            _ => {}
        }
        self.x = self.x.clamp(HALF, RAW as f64 - HALF);
        self.y = self.y.clamp(HALF, RAW as f64 - HALF);

        // Target flees the agent with jitter.
        let (dx, dy) = (self.tx - self.x, self.ty - self.y);
        let d = dx.hypot(dy).max(1.0);
        self.tx += TSPEED * dx / d + self.rng.range_f32(-0.8, 0.8) as f64;
        self.ty += TSPEED * dy / d + self.rng.range_f32(-0.8, 0.8) as f64;
        self.tx = self.tx.clamp(HALF, RAW as f64 - HALF);
        self.ty = self.ty.clamp(HALF, RAW as f64 - HALF);

        // Enemy pursues the agent.
        let (ex, ey) = (self.x - self.ex, self.y - self.ey);
        let ed = ex.hypot(ey).max(1.0);
        self.ex += ESPEED * ex / ed;
        self.ey += ESPEED * ey / ed;

        let mut reward = 0.0;
        if (self.tx - self.x).abs() < 2.0 * HALF && (self.ty - self.y).abs() < 2.0 * HALF {
            reward += 10.0;
            self.respawn_target();
        }
        let mut done = false;
        if (self.ex - self.x).abs() < 2.0 * HALF && (self.ey - self.y).abs() < 2.0 * HALF {
            reward -= 10.0;
            self.lives -= 1;
            self.ex = 10.0;
            self.ey = 10.0;
            if self.lives == 0 {
                done = true;
            }
        }
        self.ticks += 1;
        if self.ticks >= EPISODE_TICKS {
            done = true;
        }
        StepResult { reward, done }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 14);
        draw::square(buf, self.tx, self.ty, HALF, 180);
        draw::square(buf, self.ex, self.ey, HALF, 90);
        draw::square(buf, self.x, self.y, HALF, 255);
        for i in 0..self.lives {
            draw::rect(buf, 2.0 + i as f64 * 6.0, 2.0, 4.0, 4.0, 255);
        }
    }

    fn expert_action(&mut self) -> usize {
        // Flee the enemy when close; otherwise intercept the target.
        let enemy_d = (self.ex - self.x).hypot(self.ey - self.y);
        let (gx, gy) = if enemy_d < 30.0 {
            (self.x - (self.ex - self.x) * -1.0, self.y - (self.ey - self.y) * -1.0)
        } else {
            (self.tx, self.ty)
        };
        let (dx, dy) = (gx - self.x, gy - self.y);
        if dx.abs() > dy.abs() {
            if dx > 0.0 { 4 } else { 3 }
        } else if dy > 0.0 {
            2
        } else {
            1
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        for v in [self.x, self.y, self.tx, self.ty, self.ex, self.ey] {
            w.put_f64(v);
        }
        w.put_u32(self.lives);
        w.put_u32(self.ticks);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        self.x = r.f64()?;
        self.y = r.f64()?;
        self.tx = r.f64()?;
        self.ty = r.f64()?;
        self.ex = r.f64()?;
        self.ey = r.f64()?;
        self.lives = r.u32()?;
        self.ticks = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn play(expert: bool, seed: u64) -> f64 {
        let mut g = Chase::new();
        g.reset(seed);
        let mut total = 0.0;
        loop {
            let a = if expert { g.expert_action() } else { 0 };
            let r = g.step(a);
            total += r.reward;
            if r.done {
                return total;
            }
        }
    }

    #[test]
    fn terminates() {
        play(false, 1);
    }

    #[test]
    fn expert_scores_positive_margin() {
        let e: f64 = (0..3).map(|s| play(true, s)).sum();
        let n: f64 = (0..3).map(|s| play(false, s)).sum();
        assert!(e > n + 10.0, "expert {e} vs noop {n}");
    }

    #[test]
    fn catching_respawns_target_far_away() {
        let mut g = Chase::new();
        g.reset(5);
        for _ in 0..EPISODE_TICKS {
            let a = g.expert_action();
            if g.step(a).reward > 0.0 {
                let d = (g.tx - g.x).hypot(g.ty - g.y);
                assert!(d > 40.0, "target respawned too close: {d}");
                return;
            }
        }
        panic!("expert never caught the target");
    }
}
