//! Seeker: collect pellets on an open field before the timer runs out.
//!
//! Actions: 0 = NOOP, 1 = UP, 2 = DOWN, 3 = LEFT, 4 = RIGHT.
//! +1 raw reward per pellet; fixed 3000-tick episode. Tests exploration of
//! a sparse, spatially distributed reward signal (Ms. Pac-Man-ish).

use crate::util::rng::Rng;

use super::game::{draw, Game, StepResult, RAW};

const N_PELLETS: usize = 12;
const EPISODE_TICKS: u32 = 3000;
const AGENT_HALF: f64 = 4.0;
const PELLET_HALF: f64 = 3.0;

pub struct Seeker {
    rng: Rng,
    x: f64,
    y: f64,
    pellets: Vec<(f64, f64)>,
    ticks: u32,
}

impl Seeker {
    pub fn new() -> Self {
        let mut s = Seeker { rng: Rng::new(0), x: 0.0, y: 0.0, pellets: Vec::new(), ticks: 0 };
        s.reset(0);
        s
    }

    fn spawn_pellet(&mut self) -> (f64, f64) {
        (
            self.rng.range_f32(10.0, (RAW - 10) as f32) as f64,
            self.rng.range_f32(10.0, (RAW - 10) as f32) as f64,
        )
    }
}

impl Default for Seeker {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Seeker {
    fn name(&self) -> &'static str {
        "seeker"
    }

    fn num_actions(&self) -> usize {
        5
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::stream(seed, 0x5345454b); // "SEEK"
        self.x = RAW as f64 / 2.0;
        self.y = RAW as f64 / 2.0;
        self.ticks = 0;
        self.pellets = (0..N_PELLETS).map(|_| (0.0, 0.0)).collect();
        for i in 0..N_PELLETS {
            self.pellets[i] = self.spawn_pellet();
        }
    }

    fn step(&mut self, action: usize) -> StepResult {
        const SPEED: f64 = 2.0;
        match action {
            1 => self.y -= SPEED,
            2 => self.y += SPEED,
            3 => self.x -= SPEED,
            4 => self.x += SPEED,
            _ => {}
        }
        self.x = self.x.clamp(AGENT_HALF, RAW as f64 - AGENT_HALF);
        self.y = self.y.clamp(AGENT_HALF, RAW as f64 - AGENT_HALF);

        let mut reward = 0.0;
        for i in 0..self.pellets.len() {
            let (px, py) = self.pellets[i];
            if (px - self.x).abs() < AGENT_HALF + PELLET_HALF
                && (py - self.y).abs() < AGENT_HALF + PELLET_HALF
            {
                reward += 1.0;
                self.pellets[i] = self.spawn_pellet();
            }
        }
        self.ticks += 1;
        StepResult { reward, done: self.ticks >= EPISODE_TICKS }
    }

    fn render(&self, buf: &mut [u8]) {
        draw::clear(buf, 16);
        for &(px, py) in &self.pellets {
            draw::square(buf, px, py, PELLET_HALF, 170);
        }
        draw::square(buf, self.x, self.y, AGENT_HALF, 255);
        // Timer bar along the top.
        let frac = 1.0 - self.ticks as f64 / EPISODE_TICKS as f64;
        draw::rect(buf, 0.0, 0.0, RAW as f64 * frac, 2.0, 90);
    }

    fn expert_action(&mut self) -> usize {
        // Greedy chase of the nearest pellet.
        let mut best = (f64::MAX, 0usize);
        for (i, &(px, py)) in self.pellets.iter().enumerate() {
            let d = (px - self.x).powi(2) + (py - self.y).powi(2);
            if d < best.0 {
                best = (d, i);
            }
        }
        let (px, py) = self.pellets[best.1];
        if (px - self.x).abs() > (py - self.y).abs() {
            if px > self.x { 4 } else { 3 }
        } else if py > self.y {
            2
        } else {
            1
        }
    }

    fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_rng(self.rng.state());
        w.put_f64(self.x);
        w.put_f64(self.y);
        w.put_usize(self.pellets.len());
        for &(px, py) in &self.pellets {
            w.put_f64(px);
            w.put_f64(py);
        }
        w.put_u32(self.ticks);
    }

    fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> anyhow::Result<()> {
        self.rng = Rng::from_state(r.rng()?);
        self.x = r.f64()?;
        self.y = r.f64()?;
        let n = r.usize()?;
        self.pellets = (0..n).map(|_| Ok((r.f64()?, r.f64()?))).collect::<anyhow::Result<_>>()?;
        self.ticks = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_length_episode() {
        let mut g = Seeker::new();
        g.reset(1);
        let mut n = 0;
        loop {
            n += 1;
            if g.step(0).done {
                break;
            }
        }
        assert_eq!(n, EPISODE_TICKS);
    }

    #[test]
    fn expert_collects_many() {
        let mut g = Seeker::new();
        g.reset(2);
        let mut total = 0.0;
        loop {
            let a = g.expert_action();
            let r = g.step(a);
            total += r.reward;
            if r.done {
                break;
            }
        }
        assert!(total > 20.0, "expert collected only {total}");
    }

    #[test]
    fn pellets_respawn() {
        let mut g = Seeker::new();
        g.reset(3);
        for _ in 0..EPISODE_TICKS - 1 {
            let a = g.expert_action();
            g.step(a);
        }
        assert_eq!(g.pellets.len(), N_PELLETS);
    }
}
