//! Epsilon-greedy action selection over Q-value rows.

use crate::util::rng::Rng;

/// Index of the maximum Q-value (first maximum on ties — deterministic).
pub fn argmax(q: &[f32]) -> usize {
    debug_assert!(!q.is_empty());
    let mut best = 0;
    let mut best_v = q[0];
    for (i, &v) in q.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Per-thread epsilon-greedy selector with its own RNG stream, so action
/// randomness is independent of thread scheduling (determinism invariant 1).
pub struct EpsGreedy {
    rng: Rng,
    actions: usize,
}

impl EpsGreedy {
    pub fn new(seed: u64, stream: u64, actions: usize) -> Self {
        assert!(actions > 0);
        EpsGreedy { rng: Rng::stream(seed, 0xE9_5000 ^ stream), actions }
    }

    /// Select an action from one Q-row under exploration rate `eps`.
    pub fn select(&mut self, q: &[f32], eps: f64) -> usize {
        debug_assert_eq!(q.len(), self.actions);
        if self.rng.chance(eps) {
            self.rng.below_usize(self.actions)
        } else {
            argmax(q)
        }
    }

    /// Pure-random action (replay prepopulation phase).
    pub fn random(&mut self) -> usize {
        self.rng.below_usize(self.actions)
    }

    /// RNG stream position (checkpointing).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Resume the RNG stream at a saved position (checkpoint restore).
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }
}

/// Batched epsilon-greedy selection over B Q-rows: stream `j` selects from
/// `q[j*stride .. (j+1)*stride]` under exploration rate `eps_at(j)` using
/// its own policy's RNG stream. Because every stream draws from its own
/// generator, the result is identical to selecting row-by-row — batching
/// changes the memory access pattern (one pass over a contiguous Q buffer),
/// not the sampled actions.
pub fn select_rows(
    policies: &mut [EpsGreedy],
    q: &[f32],
    stride: usize,
    eps_at: impl Fn(usize) -> f64,
    out: &mut Vec<usize>,
) {
    debug_assert_eq!(q.len(), policies.len() * stride);
    out.clear();
    for (j, policy) in policies.iter_mut().enumerate() {
        out.push(policy.select(&q[j * stride..(j + 1) * stride], eps_at(j)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0, "first max wins ties");
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn eps_zero_is_greedy() {
        let mut p = EpsGreedy::new(1, 0, 4);
        let q = [0.0, 9.0, 1.0, 2.0];
        for _ in 0..100 {
            assert_eq!(p.select(&q, 0.0), 1);
        }
    }

    #[test]
    fn eps_one_is_uniform() {
        let mut p = EpsGreedy::new(2, 0, 4);
        let q = [0.0, 9.0, 1.0, 2.0];
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[p.select(&q, 1.0)] += 1;
        }
        for &c in &counts {
            assert!((4_000..6_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn intermediate_eps_mixes() {
        let mut p = EpsGreedy::new(3, 0, 2);
        let q = [0.0, 1.0];
        let n = 10_000;
        let greedy = (0..n).filter(|_| p.select(&q, 0.1) == 1).count();
        // greedy chosen ~ 0.9 + 0.1/2 = 95% of the time
        assert!((0.93..0.97).contains(&(greedy as f64 / n as f64)), "{greedy}");
    }

    #[test]
    fn select_rows_matches_row_by_row_selection() {
        let mk = || vec![EpsGreedy::new(11, 0, 3), EpsGreedy::new(11, 1, 3)];
        let q = [0.0f32, 2.0, 1.0, 5.0, 0.0, 1.0];
        let mut batched = mk();
        let mut out = Vec::new();
        let mut seq_out = Vec::new();
        let mut sequential = mk();
        for round in 0..200 {
            let eps = 0.3 + 0.001 * round as f64;
            select_rows(&mut batched, &q, 3, |_| eps, &mut out);
            let a0 = sequential[0].select(&q[0..3], eps);
            let a1 = sequential[1].select(&q[3..6], eps);
            seq_out.clear();
            seq_out.extend([a0, a1]);
            assert_eq!(out, seq_out, "round {round}");
        }
    }

    #[test]
    fn select_rows_per_row_eps() {
        // eps=0 rows are exactly greedy regardless of other rows' eps.
        let mut policies = vec![EpsGreedy::new(5, 0, 2), EpsGreedy::new(5, 1, 2)];
        let q = [0.0f32, 1.0, 1.0, 0.0];
        let mut out = Vec::new();
        for _ in 0..100 {
            select_rows(&mut policies, &q, 2, |j| if j == 0 { 0.0 } else { 1.0 }, &mut out);
            assert_eq!(out[0], 1, "eps=0 row must stay greedy");
        }
    }

    #[test]
    fn streams_independent() {
        let mut a = EpsGreedy::new(7, 0, 6);
        let mut b = EpsGreedy::new(7, 1, 6);
        let sa: Vec<usize> = (0..32).map(|_| a.random()).collect();
        let sb: Vec<usize> = (0..32).map(|_| b.random()).collect();
        assert_ne!(sa, sb);
    }
}
