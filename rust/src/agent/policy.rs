//! Epsilon-greedy action selection over Q-value rows.

use crate::util::rng::Rng;

/// Index of the maximum Q-value (first maximum on ties — deterministic).
pub fn argmax(q: &[f32]) -> usize {
    debug_assert!(!q.is_empty());
    let mut best = 0;
    let mut best_v = q[0];
    for (i, &v) in q.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Per-thread epsilon-greedy selector with its own RNG stream, so action
/// randomness is independent of thread scheduling (determinism invariant 1).
pub struct EpsGreedy {
    rng: Rng,
    actions: usize,
}

impl EpsGreedy {
    pub fn new(seed: u64, stream: u64, actions: usize) -> Self {
        assert!(actions > 0);
        EpsGreedy { rng: Rng::stream(seed, 0xE9_5000 ^ stream), actions }
    }

    /// Select an action from one Q-row under exploration rate `eps`.
    pub fn select(&mut self, q: &[f32], eps: f64) -> usize {
        debug_assert_eq!(q.len(), self.actions);
        if self.rng.chance(eps) {
            self.rng.below_usize(self.actions)
        } else {
            argmax(q)
        }
    }

    /// Pure-random action (replay prepopulation phase).
    pub fn random(&mut self) -> usize {
        self.rng.below_usize(self.actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0, "first max wins ties");
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn eps_zero_is_greedy() {
        let mut p = EpsGreedy::new(1, 0, 4);
        let q = [0.0, 9.0, 1.0, 2.0];
        for _ in 0..100 {
            assert_eq!(p.select(&q, 0.0), 1);
        }
    }

    #[test]
    fn eps_one_is_uniform() {
        let mut p = EpsGreedy::new(2, 0, 4);
        let q = [0.0, 9.0, 1.0, 2.0];
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[p.select(&q, 1.0)] += 1;
        }
        for &c in &counts {
            assert!((4_000..6_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn intermediate_eps_mixes() {
        let mut p = EpsGreedy::new(3, 0, 2);
        let q = [0.0, 1.0];
        let n = 10_000;
        let greedy = (0..n).filter(|_| p.select(&q, 0.1) == 1).count();
        // greedy chosen ~ 0.9 + 0.1/2 = 95% of the time
        assert!((0.93..0.97).contains(&(greedy as f64 / n as f64)), "{greedy}");
    }

    #[test]
    fn streams_independent() {
        let mut a = EpsGreedy::new(7, 0, 6);
        let mut b = EpsGreedy::new(7, 1, 6);
        let sa: Vec<usize> = (0..32).map(|_| a.random()).collect();
        let sb: Vec<usize> = (0..32).map(|_| b.random()).collect();
        assert_ne!(sa, sb);
    }
}
