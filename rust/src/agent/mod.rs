//! Agent substrate: action selection policies.

pub mod policy;

pub use policy::{argmax, EpsGreedy};
