//! Cross-mode determinism matrix for the pluggable replay strategies
//! (ISSUE 5, rust/DESIGN.md §11).
//!
//! Two claims are pinned end-to-end through `Coordinator::state_digest`:
//!
//! 1. **Uniform is the seed machine.** `replay_strategy = "uniform"` with
//!    `n_step = 1` routes through literally the pre-strategy code path
//!    (same "REPL" draw stream, same `assemble`, the engine's historical
//!    10-input entry), so its trajectory carries every pre-PR invariant:
//!    digest-stable, and invariant across learner_threads and prefetch —
//!    the exact pins `tests/parallel_learner.rs` established before the
//!    strategy seam existed. The draw-level identity (strategy draws ==
//!    `ReplayMemory::sample`) is pinned in `replay/strategy.rs` tests.
//!
//! 2. **Proportional is deterministic.** Prioritized trajectories are
//!    bit-identical across learner_threads {1,4} × prefetch on/off ×
//!    all four exec modes × kill-and-resume mid-run — because TD errors
//!    are bit-exact at any pool width (§9), draws advance one RNG in
//!    consumption order, and priority updates land only at window
//!    barriers (windowed modes) or in the sequential train order
//!    (inline modes).
//!
//! Async drivers run W = 1 here, matching the seed machine's historical
//! layout (standard-async is still scheduling-dependent at W > 1 — theta
//! freshness races the interlock — while concurrent-async is deterministic
//! at any W since the static block schedule; see tests/fleet.rs); the
//! synchronized drivers run W = 2.

use std::path::PathBuf;

use tempo_dqn::config::{ExecMode, ExperimentConfig, ReplayStrategy};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;

fn cfg(
    mode: ExecMode,
    strategy: ReplayStrategy,
    n_step: usize,
    learner_threads: usize,
    prefetch_batches: usize,
) -> ExperimentConfig {
    let (threads, b) = match mode {
        // Single-sampler async configs (standard needs W = 1; §7.4).
        ExecMode::Standard | ExecMode::Concurrent => (1, 2),
        ExecMode::Synchronized | ExecMode::Both => (2, 2),
    };
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.game = "seeker".into();
    cfg.mode = mode;
    cfg.threads = threads;
    cfg.envs_per_thread = b;
    cfg.learner_threads = learner_threads;
    cfg.prefetch_batches = prefetch_batches;
    cfg.replay_strategy = strategy;
    cfg.n_step = n_step;
    cfg.per_beta_anneal = 48; // anneal visibly within the smoke run
    cfg.total_steps = 192;
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 77;
    cfg
}

fn digest(cfg: &ExperimentConfig) -> u64 {
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    coord.state_digest().unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-strategy-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Kill-and-resume: run to `cut` with a checkpoint, rebuild a fresh
/// coordinator (as a new process would), resume, finish; digest must
/// match the uninterrupted machine.
fn digest_resumed(cfg: &ExperimentConfig, cut: u64, tag: &str) -> u64 {
    let dir = tmpdir(tag);
    let mut half = cfg.clone();
    half.total_steps = cut;
    half.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    half.ckpt_period = cut;
    let mut first = Coordinator::new(half, &default_artifact_dir()).unwrap();
    first.run().unwrap();
    drop(first); // the process "dies" here

    let mut full = cfg.clone();
    full.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    full.ckpt_period = cfg.total_steps;
    let mut second = Coordinator::new(full, &default_artifact_dir()).unwrap();
    assert_eq!(second.resume_from(&dir).unwrap(), cut, "{tag}: checkpoint not at the cut");
    second.run().unwrap();
    let d = second.state_digest().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    d
}

// ---------------------------------------------------------------------------
// Uniform: the pre-PR pins survive the strategy seam
// ---------------------------------------------------------------------------

#[test]
fn uniform_digest_is_stable_and_knob_invariant() {
    let base = cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 1, 1);
    let reference = digest(&base);
    // Reproducible at all (the digest would catch clock/address hashing).
    assert_eq!(reference, digest(&base), "uniform baseline not reproducible");
    // The pre-PR invariants, re-pinned through the strategy plumbing:
    // learner_threads and prefetch do not move the trajectory by a bit.
    assert_eq!(reference, digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 4, 1)),
        "learner_threads=4 moved the uniform trajectory");
    assert_eq!(reference, digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 1, 0)),
        "prefetch off moved the uniform trajectory");
    assert_eq!(reference, digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 4, 2)),
        "combined knobs moved the uniform trajectory");
}

#[test]
fn uniform_nstep_is_deterministic_and_distinct() {
    let n3 = cfg(ExecMode::Both, ReplayStrategy::Uniform, 3, 1, 1);
    let reference = digest(&n3);
    assert_eq!(reference, digest(&n3), "uniform n=3 not reproducible");
    // Same draws, different targets: the trajectory must actually change.
    assert_ne!(
        reference,
        digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 1, 1)),
        "n_step=3 did not change the trajectory"
    );
    // And the learner knobs stay bit-exact on the n-step path too.
    assert_eq!(reference, digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 3, 4, 0)),
        "learner knobs moved the uniform n-step trajectory");
}

// ---------------------------------------------------------------------------
// Proportional: the full determinism matrix
// ---------------------------------------------------------------------------

/// learner_threads {1,4} × prefetch on/off × all four exec modes: one
/// digest per mode.
#[test]
fn proportional_digest_invariant_across_learner_threads_and_prefetch() {
    for mode in ExecMode::ALL {
        let reference = digest(&cfg(mode, ReplayStrategy::Proportional, 1, 1, 1));
        assert_eq!(
            reference,
            digest(&cfg(mode, ReplayStrategy::Proportional, 1, 1, 1)),
            "{}: proportional baseline not reproducible",
            mode.name()
        );
        for (lt, pf) in [(4usize, 1usize), (1, 0), (4, 0), (4, 2)] {
            assert_eq!(
                reference,
                digest(&cfg(mode, ReplayStrategy::Proportional, 1, lt, pf)),
                "{}: learner_threads={lt} prefetch={pf} moved the prioritized trajectory",
                mode.name()
            );
        }
    }
}

/// Kill-and-resume mid-run, per exec mode (cuts window-aligned for the
/// concurrent modes, round-aligned otherwise).
#[test]
fn proportional_kill_and_resume_is_bit_exact_per_mode() {
    for mode in ExecMode::ALL {
        let base = cfg(mode, ReplayStrategy::Proportional, 1, 1, 1);
        let reference = digest(&base);
        let cut = match mode {
            ExecMode::Standard => 64,
            _ => 128,
        };
        assert_eq!(
            reference,
            digest_resumed(&base, cut, &format!("per-{}", mode.name())),
            "{}: resumed prioritized trajectory diverged",
            mode.name()
        );
    }
}

/// The combined configuration (proportional + n-step + parallel learner +
/// prefetch) survives kill-and-resume — the PR's everything-at-once pin.
#[test]
fn proportional_nstep_parallel_prefetch_resume_is_bit_exact() {
    let base = cfg(ExecMode::Both, ReplayStrategy::Proportional, 3, 4, 2);
    let reference = digest(&base);
    assert_eq!(
        reference,
        digest(&cfg(ExecMode::Both, ReplayStrategy::Proportional, 3, 1, 0)),
        "serial inline run diverged from parallel prefetched run"
    );
    assert_eq!(
        reference,
        digest_resumed(&base, 128, "per-n3-combined"),
        "combined-config resume diverged"
    );
}

/// Sanity: prioritization actually changes what is learned (the strategies
/// are not accidentally aliased), and so does the IS-weight schedule.
#[test]
fn proportional_differs_from_uniform() {
    let uniform = digest(&cfg(ExecMode::Both, ReplayStrategy::Uniform, 1, 1, 1));
    let proportional = digest(&cfg(ExecMode::Both, ReplayStrategy::Proportional, 1, 1, 1));
    assert_ne!(uniform, proportional, "proportional trajectory identical to uniform");

    let mut beta_fast = cfg(ExecMode::Both, ReplayStrategy::Proportional, 1, 1, 1);
    beta_fast.per_beta0 = 1.0; // full IS correction from the start
    assert_ne!(
        proportional,
        digest(&beta_fast),
        "β schedule has no effect on the trajectory"
    );
}

/// A proportional checkpoint refuses to resume under different PER
/// hyperparameters or a different strategy (the trajectory would split).
#[test]
fn proportional_checkpoint_refuses_mismatched_strategy_config() {
    let dir = tmpdir("per-mismatch");
    let mut base = cfg(ExecMode::Both, ReplayStrategy::Proportional, 1, 1, 1);
    base.total_steps = 64;
    base.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    base.ckpt_period = 64;
    let mut coord = Coordinator::new(base.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    drop(coord);

    let mut other = base.clone();
    other.per_alpha = 0.3;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("per_alpha"), "must name the mismatched knob: {err}");

    let mut other = base.clone();
    other.n_step = 2;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("n_step"), "must name the mismatched knob: {err}");

    let mut other = base.clone();
    other.replay_strategy = ReplayStrategy::Uniform;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("replay_strategy"), "must name the strategy: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
