//! Parallel-learner acceptance tests (ISSUE 2).
//!
//! The tentpole claim is *bit-determinism under parallelism*:
//!
//! * gradients (and therefore parameters) are bit-identical for any
//!   `learner_threads` value — the sharded Phase A / order-preserving
//!   Phase B reduction never changes an element's f32 accumulation
//!   sequence;
//! * the replay prefetch pipeline changes *when* batches are assembled,
//!   never *what* they contain — prefetch on/off yields the identical
//!   training trajectory for a pinned seed;
//! * the cache-tiled matmuls match the naive kernels elementwise.

use std::sync::Arc;

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::kernels::{
    matmul_a_bt, matmul_a_bt_tiled, matmul_acc, matmul_acc_tiled, matmul_at_b_acc,
    matmul_at_b_acc_tiled,
};
use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, QNet, TrainBatch};
use tempo_dqn::util::rng::Rng;

// ---------------------------------------------------------------------------
// (a) learner_threads ∈ {1, 2, 4} produce bit-identical parameters
// ---------------------------------------------------------------------------

fn train_batch_for(qnet: &QNet, seed: u64) -> TrainBatch {
    let [h, w, c] = qnet.spec().frame;
    let b = 32usize;
    let mut rng = Rng::new(seed);
    let frame = h * w * c;
    TrainBatch {
        states: (0..b * frame).map(|_| rng.below(256) as u8).collect(),
        next_states: (0..b * frame).map(|_| rng.below(256) as u8).collect(),
        actions: (0..b).map(|_| rng.below(qnet.spec().actions as u32) as i32).collect(),
        rewards: (0..b).map(|_| rng.f32() - 0.5).collect(),
        dones: (0..b).map(|i| if i % 5 == 0 { 1.0 } else { 0.0 }).collect(),
        ..TrainBatch::default()
    }
}

fn theta_after_steps(learner_threads: usize, double: bool) -> (Vec<u32>, Vec<u32>) {
    let manifest = Manifest::load_or_builtin(&default_artifact_dir()).expect("manifest");
    let device = Arc::new(Device::cpu_with_threads(learner_threads).expect("device"));
    let qnet = QNet::load(device, &manifest, "tiny", double, 32).expect("qnet");
    let mut losses = Vec::new();
    for step in 0..4u64 {
        let batch = train_batch_for(&qnet, 100 + step);
        losses.push(qnet.train_step(&batch, 2.5e-4).expect("train").to_bits());
        if step == 1 {
            qnet.sync_target(); // exercise a target swap mid-sequence
        }
    }
    let theta: Vec<u32> = qnet.theta_host().unwrap().iter().map(|v| v.to_bits()).collect();
    (theta, losses)
}

#[test]
fn learner_thread_counts_are_bit_identical() {
    let (theta1, losses1) = theta_after_steps(1, false);
    for threads in [2usize, 4] {
        let (theta_n, losses_n) = theta_after_steps(threads, false);
        assert_eq!(losses1, losses_n, "{threads} learner threads: loss sequence drifted");
        assert_eq!(theta1, theta_n, "{threads} learner threads: theta not bit-identical");
    }
}

#[test]
fn learner_thread_counts_are_bit_identical_double_dqn() {
    let (theta1, losses1) = theta_after_steps(1, true);
    let (theta4, losses4) = theta_after_steps(4, true);
    assert_eq!(losses1, losses4, "double-DQN loss sequence drifted");
    assert_eq!(theta1, theta4, "double-DQN theta not bit-identical");
}

// ---------------------------------------------------------------------------
// (b) prefetch on/off: identical end-to-end training trajectory
// ---------------------------------------------------------------------------

fn e2e_cfg(mode: ExecMode, learner_threads: usize, prefetch_batches: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.mode = mode;
    cfg.threads = 2;
    cfg.envs_per_thread = 2;
    cfg.learner_threads = learner_threads;
    cfg.prefetch_batches = prefetch_batches;
    cfg.total_steps = 192;
    cfg.game = "seeker".into();
    cfg.prepopulate = 300;
    cfg.replay_capacity = 16_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 33;
    cfg
}

/// Returns (returns, loss values, trains, final theta bits). Loss *steps*
/// are tagged by a racing counter in concurrent modes, so only the values
/// (which are order-deterministic) are compared.
fn run_trajectory(cfg: ExperimentConfig) -> (Vec<(u64, f64)>, Vec<u32>, u64, Vec<u32>) {
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).expect("coordinator");
    let res = coord.run().expect("run");
    let losses = res.losses.iter().map(|(_, l)| l.to_bits()).collect();
    let theta = coord
        .qnet()
        .theta_host()
        .expect("theta")
        .iter()
        .map(|v| v.to_bits())
        .collect();
    (res.returns, losses, res.trains, theta)
}

#[test]
fn prefetch_on_off_trajectories_are_identical_in_both_mode() {
    // The sync driver finishes every dispatched window, so trains and
    // theta are fully deterministic — compare everything.
    let off = run_trajectory(e2e_cfg(ExecMode::Both, 1, 0));
    let on = run_trajectory(e2e_cfg(ExecMode::Both, 1, 2));
    assert_eq!(off.0, on.0, "returns diverged with prefetch on");
    assert_eq!(off.1, on.1, "loss values diverged with prefetch on");
    assert_eq!(off.2, on.2, "train counts diverged with prefetch on");
    assert_eq!(off.3, on.3, "final theta diverged with prefetch on");
}

#[test]
fn parallel_learner_plus_prefetch_reproduces_serial_trajectory() {
    // The PR's acceptance criterion end-to-end: learner_threads=4 with
    // prefetch enabled is the SAME machine as the serial inline learner.
    let serial = run_trajectory(e2e_cfg(ExecMode::Both, 1, 0));
    let parallel = run_trajectory(e2e_cfg(ExecMode::Both, 4, 2));
    assert_eq!(serial.0, parallel.0, "returns diverged");
    assert_eq!(serial.1, parallel.1, "loss values diverged");
    assert_eq!(serial.2, parallel.2, "train counts diverged");
    assert_eq!(serial.3, parallel.3, "final theta diverged");
}

#[test]
fn async_concurrent_mode_runs_with_parallel_learner_and_prefetch() {
    // Async-mode step tickets race by design (rust/DESIGN.md §7.4), so
    // trajectories are not run-to-run comparable even without the new
    // machinery; assert the pipeline drives the async driver to completion
    // with real training and target syncs.
    let mut coord = Coordinator::new(e2e_cfg(ExecMode::Concurrent, 4, 2), &default_artifact_dir())
        .expect("coordinator");
    let res = coord.run().expect("run");
    assert!(res.steps >= 192, "steps {}", res.steps);
    assert!(res.trains >= 32, "trains {}", res.trains);
    assert!(res.target_syncs >= 2, "syncs {}", res.target_syncs);
}

// ---------------------------------------------------------------------------
// (c) tiled kernels == naive kernels, elementwise
// ---------------------------------------------------------------------------

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.chance(0.3) {
                0.0 // exercise the sparsity-skip paths
            } else {
                rng.range_f32(-3.0, 3.0)
            }
        })
        .collect()
}

#[test]
fn tiled_matmuls_match_naive_on_random_shapes() {
    let mut rng = Rng::new(0x7115D);
    for case in 0..40 {
        let m = 1 + rng.below_usize(48);
        let k = 1 + rng.below_usize(400);
        let n = 1 + rng.below_usize(150);
        let a = randvec(&mut rng, m * k);
        let b_kn = randvec(&mut rng, k * n);
        let b_mn = randvec(&mut rng, m * n);
        let b_nk = randvec(&mut rng, n * k);

        let mut naive = randvec(&mut rng, m * n);
        let mut tiled = naive.clone();
        matmul_acc(&a, &b_kn, &mut naive, m, k, n);
        matmul_acc_tiled(&a, &b_kn, &mut tiled, m, k, n);
        assert_eq!(bits(&naive), bits(&tiled), "case {case}: matmul_acc {m}x{k}x{n}");

        let mut naive = randvec(&mut rng, k * n);
        let mut tiled = naive.clone();
        matmul_at_b_acc(&a, &b_mn, &mut naive, m, k, n);
        matmul_at_b_acc_tiled(&a, &b_mn, &mut tiled, m, k, n);
        assert_eq!(bits(&naive), bits(&tiled), "case {case}: matmul_at_b_acc {m}x{k}x{n}");

        let mut naive = vec![0.0f32; m * n];
        let mut tiled = vec![f32::NAN; m * n]; // `=` kernel: junk must be overwritten
        matmul_a_bt(&a, &b_nk, &mut naive, m, k, n);
        matmul_a_bt_tiled(&a, &b_nk, &mut tiled, m, k, n);
        assert_eq!(bits(&naive), bits(&tiled), "case {case}: matmul_a_bt {m}x{k}x{n}");
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}
