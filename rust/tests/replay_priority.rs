//! Property tests for the prioritized-replay substrate (ISSUE 5):
//! sum-tree invariants, priority-index/sampleable-set agreement, and the
//! n-step assembly edge cases (rust/DESIGN.md §11).
//!
//! Like `tests/proptests.rs`, these use seeded randomized generation
//! (proptest is unavailable offline). The base seed comes from
//! `TEMPO_PROPTEST_SEED` (pinned in CI; defaults to a fixed constant) and
//! every failure message carries the case seed for reproduction.

use tempo_dqn::config::ReplayStrategy;
use tempo_dqn::replay::strategy::StrategyPlan;
use tempo_dqn::replay::{build_strategy, ReplayMemory, SampleIndex, SamplingStrategy, SumTree};
use tempo_dqn::runtime::TrainBatch;
use tempo_dqn::util::rng::Rng;

const CASES: u64 = 40;

/// Base seed: `TEMPO_PROPTEST_SEED` (CI pins it) or a fixed default.
fn base_seed() -> u64 {
    std::env::var("TEMPO_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x0C0F_FEE5)
}

// ---------------------------------------------------------------------------
// Sum-tree properties
// ---------------------------------------------------------------------------

/// Total-mass conservation under arbitrary update sequences: every
/// internal node equals the exact f64 sum of its children after any
/// interleaving of sets, so the root is a pure function of the leaves.
#[test]
fn prop_sumtree_conserves_total_mass() {
    for case in 0..CASES {
        let seed = base_seed() ^ case;
        let mut rng = Rng::new(seed);
        let leaves = 1 + rng.below_usize(300);
        let mut tree = SumTree::new(leaves);
        let mut reference = vec![0.0f64; leaves];
        for _ in 0..500 {
            let leaf = rng.below_usize(leaves);
            // Mix of zeroing (deactivation) and positive masses.
            let mass = if rng.chance(0.25) { 0.0 } else { rng.f64() * 10.0 };
            tree.set(leaf, mass);
            reference[leaf] = mass;
        }
        // Parent-sum invariant holds exactly...
        for leaf in 0..leaves {
            assert_eq!(tree.get(leaf), reference[leaf], "seed {seed}: leaf {leaf} mass");
        }
        // ...so the root only differs from a linear sum by f64 reorder.
        let linear: f64 = reference.iter().sum();
        let rel = (tree.total() - linear).abs() / linear.max(1e-12);
        assert!(rel < 1e-9, "seed {seed}: total {} vs linear {linear}", tree.total());
    }
}

/// Every sampled leaf is in `[0, len)` and carries positive mass, for the
/// whole mass range including the float edge at `u == total`.
#[test]
fn prop_sumtree_sampled_leaf_in_bounds_and_positive() {
    for case in 0..CASES {
        let seed = base_seed() ^ (0x5A17 + case);
        let mut rng = Rng::new(seed);
        let leaves = 2 + rng.below_usize(200);
        let mut tree = SumTree::new(leaves);
        // Sparse positive masses (plenty of zero leaves to avoid).
        for _ in 0..leaves / 2 + 1 {
            tree.set(rng.below_usize(leaves), rng.f64() * 5.0 + 1e-6);
        }
        let total = tree.total();
        assert!(total > 0.0);
        for k in 0..500 {
            let u = match k {
                0 => 0.0,
                1 => total, // the rounding edge
                _ => rng.f64() * total,
            };
            let leaf = tree.sample(u);
            assert!(leaf < leaves, "seed {seed}: leaf {leaf} out of range {leaves}");
            assert!(tree.get(leaf) > 0.0, "seed {seed}: sampled zero-mass leaf {leaf} at u {u}");
        }
    }
}

/// Empirical sampling frequencies track the priority masses under the
/// fixed "REPL" RNG stream (the exact stream the proportional strategy
/// draws from).
#[test]
fn sumtree_sampling_frequencies_track_priorities() {
    let mut tree = SumTree::new(8);
    // Masses 1, 2, 4, 8 on leaves 0, 2, 5, 7 -> P = 1/15, 2/15, 4/15, 8/15.
    tree.set(0, 1.0);
    tree.set(2, 2.0);
    tree.set(5, 4.0);
    tree.set(7, 8.0);
    let mut rng = Rng::stream(base_seed(), 0x5245504c); // "REPL"
    let draws = 60_000usize;
    let mut counts = [0usize; 8];
    for _ in 0..draws {
        counts[tree.sample(rng.f64() * tree.total())] += 1;
    }
    assert_eq!(counts[1] + counts[3] + counts[4] + counts[6], 0, "zero-mass leaves drawn");
    for (leaf, mass) in [(0usize, 1.0f64), (2, 2.0), (5, 4.0), (7, 8.0)] {
        let expect = mass / 15.0;
        let got = counts[leaf] as f64 / draws as f64;
        assert!(
            (got - expect).abs() < 0.02,
            "leaf {leaf}: frequency {got:.4} vs P {expect:.4} ({counts:?})"
        );
    }
}

// ---------------------------------------------------------------------------
// Priority index vs the replay's sampleable set
// ---------------------------------------------------------------------------

/// Under arbitrary multi-stream push sequences (episode boundaries,
/// wraparound), the priority index's active set always equals the uniform
/// sampler's sampleable set, and every active leaf round-trips through
/// `leaf_to_index`.
#[test]
fn prop_priority_active_set_matches_sampleable() {
    const FS: usize = 8;
    for case in 0..CASES {
        let seed = base_seed() ^ (0xAC71 + case);
        let mut rng = Rng::new(seed);
        let streams = 1 + rng.below_usize(4);
        let per = 8 + rng.below_usize(24);
        let mut replay = ReplayMemory::new(per * streams, streams, FS, 4, seed).unwrap();
        replay.enable_priorities();
        let mut starts = vec![true; streams];
        for _ in 0..3 * per * streams {
            let s = rng.below_usize(streams);
            let done = rng.chance(0.15);
            let v = rng.below(256) as u8;
            replay.push(s, &[v; FS], v, 0.0, done, starts[s]);
            starts[s] = done;
            let pi = replay.priorities().unwrap();
            assert_eq!(
                pi.active_count(),
                replay.sampleable(),
                "seed {seed}: active set drifted from sampleable set"
            );
        }
        let pi = replay.priorities().unwrap();
        let mut active = 0;
        for leaf in 0..replay.capacity() {
            if pi.value(leaf) > 0.0 {
                active += 1;
                assert!(replay.leaf_to_index(leaf).is_some(), "seed {seed}: unmappable active leaf");
            } else {
                assert!(replay.leaf_to_index(leaf).is_none(), "seed {seed}: mappable inactive leaf");
            }
        }
        assert_eq!(active, replay.sampleable(), "seed {seed}");
    }
}

/// Draws through the full proportional strategy respect the per-batch
/// contract: weights in (0, 1] with at least one exactly 1.0, assembled
/// batches carry boot_gammas, and with uniform (never-updated) priorities
/// all weights collapse to exactly 1.
#[test]
fn proportional_fill_batch_contract() {
    const FS: usize = 8;
    let plan = StrategyPlan {
        kind: ReplayStrategy::Proportional,
        per_alpha: 0.6,
        per_beta0: 0.4,
        per_beta_anneal: 1_000,
        n_step: 3,
        gamma: 0.99,
    };
    let mut replay = ReplayMemory::new(256, 2, FS, 4, base_seed()).unwrap();
    replay.enable_priorities();
    for v in 0..60u8 {
        replay.push(0, &[v; FS], v, 0.5, v % 11 == 10, v == 0 || v % 11 == 0);
        replay.push(1, &[v; FS], v, 0.0, v % 13 == 12, v == 0 || v % 13 == 0);
    }
    let mut strat = build_strategy(&plan, Rng::new(base_seed()).state(), 0);
    let mut batch = TrainBatch::default();
    for _ in 0..10 {
        strat.fill_batch(&replay, 16, &mut batch).unwrap();
        assert_eq!(batch.weights.len(), 16);
        assert_eq!(batch.boot_gammas.len(), 16);
        for &w in &batch.weights {
            // Never-updated priorities are all equal -> every weight is 1.
            assert_eq!(w, 1.0, "uniform-priority draw must have unit weights");
        }
        let gamma = plan.gamma as f32;
        for &g in &batch.boot_gammas {
            assert!(g > 0.0 && g <= gamma, "boot gamma {g} out of (0, γ]");
        }
        // Pair the batch with synthetic TD errors and apply at a "barrier".
        let td: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.1).collect();
        strat.record_td(&td);
        assert!(strat.has_pending());
        strat.apply_updates(&mut replay);
        assert!(!strat.has_pending());
    }
    // After unequal TD updates the priorities differ: the (0,1] bound and
    // batch-max normalization must now hold on genuinely non-trivial
    // weights, with at least one weight strictly inside the interval.
    strat.fill_batch(&replay, 64, &mut batch).unwrap();
    let mut saw_unit = false;
    let mut saw_interior = false;
    for &w in &batch.weights {
        assert!(w > 0.0 && w <= 1.0, "IS weight {w} out of (0,1]");
        saw_unit |= w == 1.0;
        saw_interior |= w < 1.0;
    }
    assert!(saw_unit, "batch-max normalization must pin one weight at 1");
    assert!(saw_interior, "updated priorities must produce non-trivial IS weights");
}

/// TD updates raise a transition's sampling frequency (the point of PER):
/// after boosting one leaf's priority far above the rest, it dominates
/// the drawn picks.
#[test]
fn updated_priorities_shift_the_draw_distribution() {
    const FS: usize = 8;
    let plan = StrategyPlan {
        kind: ReplayStrategy::Proportional,
        per_alpha: 1.0,
        per_beta0: 0.4,
        per_beta_anneal: 1_000,
        n_step: 1,
        gamma: 0.99,
    };
    let mut replay = ReplayMemory::new(64, 1, FS, 4, 1).unwrap();
    replay.enable_priorities();
    for v in 0..40u8 {
        replay.push(0, &[v; FS], v, 0.0, false, v == 0);
    }
    let mut strat = build_strategy(&plan, Rng::new(9).state(), 0);
    let mut batch = TrainBatch::default();
    // Draw until slot 10 (action byte 10) appears, then hand back a TD
    // vector that is huge exactly there and tiny elsewhere.
    let mut boosted = false;
    for _ in 0..20 {
        strat.fill_batch(&replay, 32, &mut batch).unwrap();
        let td: Vec<f32> =
            batch.actions.iter().map(|&a| if a == 10 { 50.0 } else { 1e-3 }).collect();
        boosted |= batch.actions.contains(&10);
        strat.record_td(&td);
        strat.apply_updates(&mut replay);
        if boosted {
            break;
        }
    }
    assert!(boosted, "slot 10 never drawn in 640 uniform-priority draws");
    // The boosted transition now carries ~50 of the total mass (every
    // other priority is <= 1.0 across <= 36 sampleable slots), so the
    // next batch must oversample it massively vs the uniform 1/36 ≈ 2.8%.
    strat.fill_batch(&replay, 64, &mut batch).unwrap();
    let hits = batch.actions.iter().filter(|&&a| a == 10).count();
    assert!(hits > 64 / 5, "boosted transition not oversampled: {hits}/64");
}

// ---------------------------------------------------------------------------
// n-step assembly properties (against a naive reference model)
// ---------------------------------------------------------------------------

/// Naive n-step reference: full transition list per stream, scan forward.
struct NaiveStream {
    rewards: Vec<f32>,
    dones: Vec<bool>,
    starts: Vec<bool>,
}

impl NaiveStream {
    /// (n-step return, done-within-window, m) starting at index i.
    fn window(&self, i: usize, n: usize, gamma: f32) -> (f32, bool, usize) {
        let mut ret = 0.0f32;
        let mut disc = 1.0f32;
        let mut m = 0usize;
        for k in 0..n {
            let j = i + k;
            if k > 0 {
                if j >= self.rewards.len() || self.starts[j] {
                    break;
                }
                if !self.dones[j] && j + 1 >= self.rewards.len() {
                    break;
                }
            }
            if k == 0 {
                ret = self.rewards[j];
            } else {
                ret += disc * self.rewards[j];
            }
            m = k + 1;
            if self.dones[j] {
                return (ret, true, m);
            }
            disc *= gamma;
        }
        (ret, false, m)
    }
}

/// Randomized episodes: the assembled n-step batch agrees with the naive
/// reference on return/done/γᵐ for every sampleable start index, for a
/// spread of horizons (including n far beyond the episode length).
#[test]
fn prop_nstep_assembly_matches_naive_reference() {
    const FS: usize = 8;
    const STACK: usize = 4;
    for case in 0..CASES {
        let seed = base_seed() ^ (0x215E9 + case);
        let mut rng = Rng::new(seed);
        let cap = 32 + rng.below_usize(32);
        let mut replay = ReplayMemory::new(cap, 1, FS, STACK, seed).unwrap();
        let mut naive = NaiveStream { rewards: Vec::new(), dones: Vec::new(), starts: Vec::new() };
        let mut start = true;
        let pushes = cap / 2 + rng.below_usize(cap); // may or may not wrap
        for i in 0..pushes {
            let done = rng.chance(0.2);
            let reward = (rng.f64() as f32 - 0.5) * 4.0;
            replay.push(0, &[i as u8; FS], i as u8, reward, done, start);
            naive.rewards.push(reward);
            naive.dones.push(done);
            naive.starts.push(start);
            start = done;
        }
        // The naive model keeps every pushed transition; the ring only the
        // last `len`. Align indices to the ring's oldest entry.
        let len = replay.len();
        let offset = pushes - len;
        let gamma = 0.9f32;
        for n in [1usize, 2, 3, 7, 64] {
            let picks: Vec<SampleIndex> = (STACK - 1..len - 1)
                .map(|slot| SampleIndex { stream: 0, slot })
                .collect();
            let mut batch = TrainBatch::default();
            replay.assemble_nstep(&picks, n, gamma, &mut batch);
            // The naive scan sees only what the ring retained: the last
            // `len` transitions (everything older was overwritten).
            let tail = NaiveStream {
                rewards: naive.rewards[offset..].to_vec(),
                dones: naive.dones[offset..].to_vec(),
                starts: naive.starts[offset..].to_vec(),
            };
            for (b, pick) in picks.iter().enumerate() {
                let (want_ret, want_done, want_m) = tail.window(pick.slot, n, gamma);
                assert_eq!(
                    batch.rewards[b].to_bits(),
                    want_ret.to_bits(),
                    "seed {seed} n {n} slot {}: return",
                    pick.slot
                );
                assert_eq!(
                    batch.dones[b] == 1.0,
                    want_done,
                    "seed {seed} n {n} slot {}: done flag",
                    pick.slot
                );
                let mut bg = gamma;
                for _ in 1..want_m {
                    bg *= gamma;
                }
                assert_eq!(
                    batch.boot_gammas[b].to_bits(),
                    bg.to_bits(),
                    "seed {seed} n {n} slot {}: boot gamma (m {want_m})",
                    pick.slot
                );
                // Non-terminal windows bootstrap from the state ending at
                // slot + m: its newest frame byte is the pushed id.
                if !want_done {
                    let sb = FS * STACK;
                    let newest = batch.next_states[b * sb + (STACK - 1)];
                    assert_eq!(
                        newest as usize,
                        offset + pick.slot + want_m,
                        "seed {seed} n {n} slot {}: bootstrap state",
                        pick.slot
                    );
                }
            }
        }
    }
}
