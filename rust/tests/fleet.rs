//! End-to-end tests for the distributed sampler fleet (ISSUE 8,
//! rust/DESIGN.md §14), pinning the two-tier determinism contract through
//! real processes and real sockets:
//!
//! * **replicated** (`fleet_lag = 0`): a fleet run — learner in-process,
//!   sampler workers as spawned `fleet-sampler` processes of the actual
//!   binary — lands on the *same* `state_digest` as the single-process
//!   machine, and its checkpoints cross the single↔fleet boundary in both
//!   directions, including kill-and-resume mid-run.
//! * **relaxed** (`fleet_lag = 1`): reproducible run-to-run (staleness is
//!   a pure function of the window index), but a measurably *different*
//!   trajectory — shown at the loss level, not just the digest (the
//!   digest already covers the retained theta ring).
//!
//! The failure half of §14 is pinned the way tests/checkpoint_resume.rs
//! pins checkpoint corruption: every refusal and every wire fault must
//! surface as a named error (mismatched config knob, protocol version,
//! checksum, disconnect, heartbeat silence).
//!
//! Unix-only: the integration fleet runs over unix sockets (the frame and
//! endpoint layers carry their own platform-neutral unit tests).
#![cfg(unix)]

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Duration;

use tempo_dqn::config::{ExecMode, ExperimentConfig, ReplayStrategy};
use tempo_dqn::coordinator::fleet::fingerprint_text;
use tempo_dqn::coordinator::{spawn_local_samplers, Coordinator, FleetOpts, TrainResult};
use tempo_dqn::net::{Endpoint, Msg};
use tempo_dqn::runtime::default_artifact_dir;

const BIN: &str = env!("CARGO_BIN_EXE_tempo-dqn");

/// Fleet-shaped smoke config: W = 2 sampler slots x B = 2 streams,
/// three windows of C = 64 (64 % 4 == 0 and 192 % 64 == 0, the
/// window-exact geometry fleet execution requires).
fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.game = "seeker".into();
    cfg.mode = ExecMode::Concurrent;
    cfg.threads = 2;
    cfg.envs_per_thread = 2;
    cfg.total_steps = 192;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.seed = 77;
    cfg.fleet_samplers = 2;
    cfg.fleet_timeout_ms = 30_000;
    cfg
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn sock_addr(tag: &str) -> String {
    let p = std::env::temp_dir().join(format!("tempo-fleet-{tag}-{}.sock", std::process::id()));
    format!("unix:{}", p.display())
}

/// The single-process reference trajectory for `cfg`.
fn single_run(cfg: &ExperimentConfig) -> (u64, TrainResult) {
    let mut solo = cfg.clone();
    solo.fleet_samplers = 0;
    let mut coord = Coordinator::new(solo, &default_artifact_dir()).unwrap();
    let res = coord.run().unwrap();
    (coord.state_digest().unwrap(), res)
}

/// Host a fleet learner in-process with `cfg.fleet_samplers` worker
/// processes of the real binary (spawned first; they retry-connect until
/// the learner binds). Returns the final digest and the run result.
fn fleet_run(cfg: &ExperimentConfig, tag: &str, resume: Option<&Path>) -> (u64, TrainResult) {
    let bind = sock_addr(tag);
    let mut children = spawn_local_samplers(Path::new(BIN), cfg, &bind, cfg.fleet_samplers)
        .expect("spawning sampler worker processes");
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    if let Some(dir) = resume {
        coord.resume_from(dir).unwrap();
    }
    let run = coord.run_fleet(&FleetOpts { bind, samplers: cfg.fleet_samplers }, None);
    if run.is_err() {
        for child in &mut children {
            let _ = child.kill();
        }
    }
    for (i, child) in children.iter_mut().enumerate() {
        let status = child.wait().expect("waiting on a sampler process");
        if run.is_ok() {
            assert!(status.success(), "{tag}: sampler {i} exited with {status}");
        }
    }
    let res = run.unwrap_or_else(|e| panic!("{tag}: fleet learner failed: {e:#}"));
    (coord.state_digest().unwrap(), res)
}

// ---------------------------------------------------------------------------
// Replicated tier: the fleet IS the single-process trajectory
// ---------------------------------------------------------------------------

#[test]
fn replicated_fleet_is_bit_identical_to_single_process() {
    let base = cfg();
    let (reference, solo_res) = single_run(&base);
    assert_eq!(reference, single_run(&base).0, "single-process baseline not reproducible");

    let (two, res) = fleet_run(&base, "repl2", None);
    assert_eq!(two, reference, "2-process fleet diverged from the single-process digest");
    // The reported trajectory must match too, not just the machine bytes.
    assert_eq!(res.steps, 192);
    assert_eq!(res.trains, solo_res.trains);
    assert_eq!(res.target_syncs, solo_res.target_syncs);
    assert_eq!(res.losses, solo_res.losses, "fleet loss curve differs");
    assert_eq!(res.returns, solo_res.returns, "fleet episode returns differ");

    // One worker owning BOTH slots is the same trajectory again.
    let mut one_proc = base.clone();
    one_proc.fleet_samplers = 1;
    let (one, _) = fleet_run(&one_proc, "repl1", None);
    assert_eq!(one, reference, "1-process fleet (all slots on one worker) diverged");
}

#[test]
fn replicated_fleet_matches_single_process_under_prioritized_replay() {
    let mut c = cfg();
    c.replay_strategy = ReplayStrategy::Proportional;
    c.per_beta_anneal = 48;
    let (reference, _) = single_run(&c);
    let (fleet, _) = fleet_run(&c, "per", None);
    assert_eq!(
        fleet, reference,
        "prioritized fleet diverged (barrier-side priority updates must see the same draws)"
    );
}

/// Checkpoints cross the single↔fleet boundary freely, in both
/// directions, through a mid-run kill.
#[test]
fn fleet_checkpoints_cross_the_process_boundary_bit_exactly() {
    let base = cfg();
    let (reference, _) = single_run(&base);

    // Phase 1: a fleet run "dies" at step 64 with a checkpoint on disk.
    let dir = tmpdir("kr");
    let mut half = base.clone();
    half.total_steps = 64;
    half.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    half.ckpt_period = 64;
    fleet_run(&half, "kr-half", None);

    // Phase 2a: a fresh fleet learner (new samplers too) resumes it.
    let (fleet_resumed, _) = fleet_run(&base, "kr-rest", Some(&dir));
    assert_eq!(fleet_resumed, reference, "fleet -> fleet kill-and-resume diverged");

    // Phase 2b: the same fleet checkpoint resumes single-process.
    let mut solo = base.clone();
    solo.fleet_samplers = 0;
    let mut coord = Coordinator::new(solo, &default_artifact_dir()).unwrap();
    assert_eq!(coord.resume_from(&dir).unwrap(), 64, "checkpoint not at the cut");
    coord.run().unwrap();
    assert_eq!(
        coord.state_digest().unwrap(),
        reference,
        "fleet checkpoint resumed single-process diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);

    // Phase 2c: and a single-process checkpoint resumes as a fleet.
    let sdir = tmpdir("kr-solo");
    let mut shalf = base.clone();
    shalf.fleet_samplers = 0;
    shalf.total_steps = 64;
    shalf.ckpt_dir = Some(sdir.to_string_lossy().into_owned());
    shalf.ckpt_period = 64;
    Coordinator::new(shalf, &default_artifact_dir()).unwrap().run().unwrap();
    let (cross, _) = fleet_run(&base, "kr-solo-rest", Some(&sdir));
    assert_eq!(cross, reference, "single-process checkpoint resumed as a fleet diverged");
    let _ = std::fs::remove_dir_all(&sdir);
}

// ---------------------------------------------------------------------------
// Relaxed tier: deterministic staleness, different trajectory
// ---------------------------------------------------------------------------

#[test]
fn relaxed_lag_is_reproducible_and_measurably_diverges() {
    let mut lagged = cfg();
    lagged.fleet_lag = 1;
    let (a, res_a) = fleet_run(&lagged, "lag-a", None);
    let (b, res_b) = fleet_run(&lagged, "lag-b", None);
    assert_eq!(a, b, "relaxed (lag=1) fleet not reproducible run-to-run");
    assert_eq!(res_a.losses, res_b.losses, "relaxed loss curve not reproducible");
    assert_eq!(res_a.returns, res_b.returns, "relaxed returns not reproducible");

    // Divergence from the replicated trajectory must show up in the
    // trained losses, not merely in the digest (the digest alone would be
    // a vacuous check: it covers the retained theta ring, which is
    // non-empty exactly when lag > 0).
    let (reference, solo_res) = single_run(&cfg());
    assert_ne!(a, reference, "lag=1 digest did not diverge");
    assert_ne!(
        res_a.losses, solo_res.losses,
        "staleness must move the trained trajectory itself, not just the theta ring bytes"
    );
    // Same step budget and train schedule either way.
    assert_eq!(res_a.steps, solo_res.steps);
    assert_eq!(res_a.trains, solo_res.trains);
}

// ---------------------------------------------------------------------------
// Failure semantics: every refusal and wire fault is a named error
// ---------------------------------------------------------------------------

#[test]
fn mismatched_sampler_config_is_refused_at_the_handshake_by_name() {
    let mut learner_cfg = cfg();
    learner_cfg.fleet_samplers = 1;
    let mut sampler_cfg = learner_cfg.clone();
    sampler_cfg.seed = 78; // one trajectory knob off

    let bind = sock_addr("mismatch");
    let mut children =
        spawn_local_samplers(Path::new(BIN), &sampler_cfg, &bind, 1).unwrap();
    let mut coord = Coordinator::new(learner_cfg, &default_artifact_dir()).unwrap();
    let err = coord
        .run_fleet(&FleetOpts { bind, samplers: 1 }, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("seed"), "refusal must name the mismatched knob: {err}");
    assert!(err.contains("refusing"), "{err}");
    let status = children[0].wait().unwrap();
    assert!(!status.success(), "a refused sampler must exit nonzero");
}

#[test]
fn fleet_launch_refusals_name_the_offending_knob() {
    let base = cfg();
    let never = "unix:/tmp/tempo-fleet-never-bound.sock".to_string();
    let mut coord = Coordinator::new(base.clone(), &default_artifact_dir()).unwrap();
    let err = coord
        .run_fleet(&FleetOpts { bind: never.clone(), samplers: 0 }, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("at least one sampler"), "{err}");
    let err = coord
        .run_fleet(&FleetOpts { bind: never.clone(), samplers: 3 }, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("more sampler processes"), "{err}");

    let mut sync = base;
    sync.mode = ExecMode::Synchronized;
    let mut coord = Coordinator::new(sync, &default_artifact_dir()).unwrap();
    let err = coord
        .run_fleet(&FleetOpts { bind: never, samplers: 2 }, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("concurrent"), "{err}");
}

/// Host a real learner expecting one sampler; return its error chain.
fn learner_expecting_failure(cfg: ExperimentConfig, bind: String) -> std::thread::JoinHandle<String> {
    std::thread::spawn(move || {
        let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
        let err = coord
            .run_fleet(&FleetOpts { bind, samplers: 1 }, None)
            .expect_err("the learner must fail against a faulty peer");
        format!("{err:#}")
    })
}

/// The wire corruption matrix, end-to-end against a live learner: each
/// fault class surfaces as its named error (mirroring the frame-level
/// matrix in src/net/frame.rs and the checkpoint matrix in
/// tests/checkpoint_resume.rs).
#[test]
fn wire_faults_surface_as_named_learner_errors() {
    let mut base = cfg();
    base.fleet_samplers = 1;

    // (a) protocol version bump -> refused at the handshake, by version.
    {
        let bind = sock_addr("ver");
        let learner = learner_expecting_failure(base.clone(), bind.clone());
        let mut conn =
            Endpoint::parse(&bind).unwrap().connect(Duration::from_secs(10)).unwrap();
        let mut bytes = Vec::new();
        Msg::Hello { fingerprint: fingerprint_text(&base) }.send(&mut bytes).unwrap();
        bytes[4] += 1; // the version byte (frame header offset 4)
        conn.write_all(&bytes).unwrap();
        conn.flush().unwrap();
        let err = learner.join().unwrap();
        assert!(err.contains("wire protocol version"), "{err}");
    }

    // (b) a flipped payload byte -> checksum mismatch, naming the message.
    {
        let bind = sock_addr("flip");
        let learner = learner_expecting_failure(base.clone(), bind.clone());
        let mut conn =
            Endpoint::parse(&bind).unwrap().connect(Duration::from_secs(10)).unwrap();
        let mut bytes = Vec::new();
        Msg::Hello { fingerprint: fingerprint_text(&base) }.send(&mut bytes).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        conn.write_all(&bytes).unwrap();
        conn.flush().unwrap();
        let err = learner.join().unwrap();
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("hello"), "must name the corrupted message: {err}");
    }

    // (c) a sampler that handshakes, then crashes before its first upload
    // -> a disconnect error naming the sampler's slot range.
    {
        let bind = sock_addr("crash");
        let learner = learner_expecting_failure(base.clone(), bind.clone());
        let mut conn =
            Endpoint::parse(&bind).unwrap().connect(Duration::from_secs(10)).unwrap();
        Msg::Hello { fingerprint: fingerprint_text(&base) }.send(&mut conn).unwrap();
        match Msg::recv(&mut conn).unwrap() {
            Msg::HelloAck { .. } => {}
            other => panic!("expected hello-ack, got {}", other.name()),
        }
        drop(conn); // the "crash"
        let err = learner.join().unwrap();
        assert!(err.contains("sampler(slots 0..2)"), "must name the peer: {err}");
        assert!(err.contains("connection closed"), "{err}");
    }

    // (d) a sampler that goes silent -> the heartbeat timeout, named.
    {
        let mut quick = base.clone();
        quick.fleet_timeout_ms = 400;
        let bind = sock_addr("silent");
        let learner = learner_expecting_failure(quick.clone(), bind.clone());
        let mut conn =
            Endpoint::parse(&bind).unwrap().connect(Duration::from_secs(10)).unwrap();
        Msg::Hello { fingerprint: fingerprint_text(&quick) }.send(&mut conn).unwrap();
        match Msg::recv(&mut conn).unwrap() {
            Msg::HelloAck { .. } => {}
            other => panic!("expected hello-ack, got {}", other.name()),
        }
        // Stay connected but say nothing; the learner's read timeout fires.
        let err = learner.join().unwrap();
        assert!(err.contains("heartbeat timeout"), "{err}");
        drop(conn);
    }
}
