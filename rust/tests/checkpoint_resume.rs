//! Bit-exact checkpoint/resume acceptance tests (ISSUE 3 / DESIGN.md §10).
//!
//! The core claim: train 2N windows uninterrupted vs. train N → checkpoint
//! → NEW coordinator (fresh machine, as a new process would build) →
//! resume → train N, and the final machine state — parameters, RMSProp
//! accumulators, target net, replay contents and push count, every RNG
//! stream position, and the evaluation history — is bitwise identical.
//! Verified through `Coordinator::state_digest()`, an FNV over exactly
//! those bytes, for both driver families and learner_threads ∈ {1, 4}.

use std::path::PathBuf;

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;

fn base_cfg(mode: ExecMode, threads: usize, b: usize, learner_threads: usize, steps: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.game = "seeker".into();
    cfg.mode = mode;
    cfg.threads = threads;
    cfg.envs_per_thread = b;
    cfg.learner_threads = learner_threads;
    cfg.total_steps = steps;
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.target_update_period = 64; // C: 4 windows at steps = 256
    cfg.train_period = 4;
    cfg.seed = 42;
    cfg
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-resume-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Uninterrupted run to `cfg.total_steps`; returns the machine digest.
fn run_uninterrupted(cfg: &ExperimentConfig) -> u64 {
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    coord.state_digest().unwrap()
}

/// Interrupted run: train to `cut` with checkpointing (a shortened
/// total_steps plays the role of the kill), then resume in a brand-new
/// coordinator — a fresh machine exactly like a new process — extend the
/// budget back to the full total, and run to completion.
///
/// NOTE: this kill-by-shortened-budget trick requires `cut` to be
/// block-aligned (B divides it) — a run whose *total* lands mid-block
/// clamps the final block, which a mid-run segment never does. The
/// C-not-multiple-of-B case uses run_for segmentation instead (below).
fn run_interrupted(cfg: &ExperimentConfig, cut: u64, tag: &str) -> u64 {
    let dir = tmpdir(tag);
    let mut half = cfg.clone();
    half.total_steps = cut;
    half.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    half.ckpt_period = cut; // one checkpoint, at the cut
    let mut first = Coordinator::new(half.clone(), &default_artifact_dir()).unwrap();
    first.run().unwrap();
    drop(first); // the process "dies" here

    let mut full = cfg.clone();
    full.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    full.ckpt_period = cfg.total_steps; // no further mid-run checkpoints
    let mut second = Coordinator::new(full, &default_artifact_dir()).unwrap();
    let resumed_at = second.resume_from(&dir).unwrap();
    assert_eq!(resumed_at, cut, "checkpoint must sit exactly at the cut");
    second.run().unwrap();
    let digest = second.state_digest().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    digest
}

fn assert_bit_exact(cfg: ExperimentConfig, cut: u64, tag: &str) {
    let uninterrupted = run_uninterrupted(&cfg);
    let resumed = run_interrupted(&cfg, cut, tag);
    assert_eq!(
        uninterrupted, resumed,
        "{tag}: resumed trajectory diverged from the uninterrupted run"
    );
    // The digest itself must be reproducible (sanity: not hashing clocks).
    assert_eq!(uninterrupted, run_uninterrupted(&cfg), "{tag}: baseline not reproducible");
}

// ---- the acceptance matrix: both driver families × learner widths --------

#[test]
fn both_mode_resume_is_bit_exact_serial_learner() {
    // Synchronized driver, Algorithm 1, W×B = 4 streams, 2N = 4 windows.
    assert_bit_exact(base_cfg(ExecMode::Both, 2, 2, 1, 256), 128, "both-lt1");
}

#[test]
fn both_mode_resume_is_bit_exact_parallel_learner_with_prefetch() {
    // learner_threads = 4 shards gradients; prefetch pipeline double-buffers
    // batch assembly. Both are bit-exact knobs, so the digest must match the
    // serial uninterrupted machine too — pin resumed == uninterrupted here.
    let cfg = base_cfg(ExecMode::Both, 2, 2, 4, 256);
    assert_eq!(cfg.prefetch_batches, 1, "prefetch on by default");
    assert_bit_exact(cfg, 128, "both-lt4");
}

#[test]
fn concurrent_async_resume_is_bit_exact_serial_learner() {
    // W = 1 keeps this on the seed machine's historical layout (the static
    // block schedule has since made concurrent-async deterministic at any
    // W — pinned in tests/fleet.rs); B = 2 exercises block quantization at
    // the window barrier.
    assert_bit_exact(base_cfg(ExecMode::Concurrent, 1, 2, 1, 256), 128, "conc-lt1");
}

#[test]
fn concurrent_async_resume_is_bit_exact_parallel_learner() {
    assert_bit_exact(base_cfg(ExecMode::Concurrent, 1, 2, 4, 256), 128, "conc-lt4");
}

#[test]
fn concurrent_async_resume_is_bit_exact_when_c_not_multiple_of_b() {
    // C = 64, B = 3: window boundaries are not block-aligned, so the
    // checkpoint lands at the *block-rounded* window coverage (129, not
    // 128) and the straddling block must have run WHOLE — truncating it at
    // the segment bound would step a prefix of the env streams and diverge.
    let cfg = base_cfg(ExecMode::Concurrent, 1, 3, 1, 192);
    let uninterrupted = run_uninterrupted(&cfg);

    let dir = tmpdir("conc-b3");
    let mut with_ckpt = cfg.clone();
    with_ckpt.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    with_ckpt.ckpt_period = 128;
    let mut first = Coordinator::new(with_ckpt.clone(), &default_artifact_dir()).unwrap();
    first.run_for(Some(128)).unwrap(); // quantizes to the 128 window...
    assert_eq!(first.completed_steps(), 129, "...whose coverage block-rounds to 129");
    drop(first); // the process "dies" here

    let mut second = Coordinator::new(with_ckpt, &default_artifact_dir()).unwrap();
    assert_eq!(second.resume_from(&dir).unwrap(), 129);
    second.run().unwrap();
    assert_eq!(
        second.state_digest().unwrap(),
        uninterrupted,
        "C%B!=0: resumed trajectory diverged from the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn standard_mode_resume_is_bit_exact() {
    // Original DQN control flow, single thread (the deterministic config).
    assert_bit_exact(base_cfg(ExecMode::Standard, 1, 1, 1, 128), 64, "std");
}

#[test]
fn synchronized_mode_resume_is_bit_exact() {
    // Sync driver without Concurrent Training: every round end is a quiesce
    // point, so the cut need not be window-aligned — only round-aligned
    // (W×B = 2 divides 60).
    assert_bit_exact(base_cfg(ExecMode::Synchronized, 2, 1, 1, 128), 60, "sync-std");
}

#[test]
fn eval_points_survive_resume_bitwise() {
    let mut cfg = base_cfg(ExecMode::Both, 2, 1, 1, 256);
    cfg.eval_period = 64; // one eval per window barrier
    cfg.eval_episodes = 2;

    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    let res = coord.run().unwrap();
    assert!(res.evals.len() >= 3, "expected evals at 64/128/192/256, got {}", res.evals.len());
    let baseline = coord.state_digest().unwrap();
    let baseline_evals: Vec<(u64, u64, u64)> = res
        .evals
        .iter()
        .map(|e| (e.step, e.mean_return.to_bits(), e.std_return.to_bits()))
        .collect();

    let resumed = run_interrupted(&cfg, 128, "evals");
    assert_eq!(baseline, resumed, "digest (which covers the eval history) must match");

    // And explicitly: the eval points of a fresh uninterrupted run are
    // bitwise stable (guards against nondeterministic eval scheduling).
    let mut again = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let res2 = again.run().unwrap();
    let evals2: Vec<(u64, u64, u64)> = res2
        .evals
        .iter()
        .map(|e| (e.step, e.mean_return.to_bits(), e.std_return.to_bits()))
        .collect();
    assert_eq!(baseline_evals, evals2);
}

#[test]
fn periodic_checkpoints_accumulate_and_latest_wins() {
    let dir = tmpdir("periodic");
    let mut cfg = base_cfg(ExecMode::Both, 2, 1, 1, 256);
    cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    cfg.ckpt_period = 64;
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    let steps: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().into_string().ok())
        .filter(|n| n.starts_with("step_"))
        .collect();
    assert!(steps.len() >= 4, "one checkpoint per 64-step window: {steps:?}");
    let latest = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    assert!(latest.ends_with("step_000000000256"), "{latest:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- failure modes: corrupt / truncated / mismatched checkpoints ---------

fn write_one_checkpoint(tag: &str) -> (PathBuf, ExperimentConfig) {
    let dir = tmpdir(tag);
    let mut cfg = base_cfg(ExecMode::Both, 2, 1, 1, 64);
    cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    cfg.ckpt_period = 64;
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    (dir, cfg)
}

#[test]
fn corrupt_checkpoint_fails_with_clear_error_not_corrupt_state() {
    let (dir, cfg) = write_one_checkpoint("corrupt");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let state = ckpt.join("state.bin");
    let mut bytes = std::fs::read(&state).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&state, &bytes).unwrap();

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_checkpoint_fails_with_clear_error() {
    let (dir, cfg) = write_one_checkpoint("truncated");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let state = ckpt.join("state.bin");
    let bytes = std::fs::read(&state).unwrap();
    std::fs::write(&state, &bytes[..bytes.len() / 3]).unwrap();

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = format!("{:#}", coord.resume_from(&dir).unwrap_err());
    assert!(err.contains("truncated"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_config_refuses_to_resume() {
    let (dir, cfg) = write_one_checkpoint("mismatch");
    // A different training seed is a different trajectory — resuming would
    // silently splice two runs together. It must be refused, with the
    // offending field named.
    let mut other = cfg.clone();
    other.seed = 43;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("different configuration"), "unexpected error: {err}");
    assert!(err.contains("seed"), "must name the mismatched field: {err}");

    // A different W×B layout likewise.
    let mut other = cfg.clone();
    other.envs_per_thread = 2;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("envs_per_thread"), "unexpected error: {err}");

    // Extending total_steps is explicitly allowed (that is how a resumed
    // run continues past the original budget).
    let mut extended = cfg.clone();
    extended.total_steps = 128;
    let mut coord = Coordinator::new(extended, &default_artifact_dir()).unwrap();
    assert_eq!(coord.resume_from(&dir).unwrap(), 64);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- the proportional strategy's sum-tree/β-anneal section ---------------

/// Write one checkpoint of a proportional (prioritized-replay) run; its
/// "priorities" section carries the PER hyperparameters and the sum-tree
/// state and sits last in state.bin (no evaluator at smoke scale), so
/// truncation lands on it.
fn write_proportional_checkpoint(tag: &str) -> (PathBuf, ExperimentConfig) {
    let dir = tmpdir(tag);
    let mut cfg = base_cfg(ExecMode::Both, 2, 1, 1, 64);
    cfg.replay_strategy = tempo_dqn::config::ReplayStrategy::Proportional;
    cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    cfg.ckpt_period = 64;
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    (dir, cfg)
}

#[test]
fn corrupt_priorities_section_fails_with_clear_error() {
    let (dir, cfg) = write_proportional_checkpoint("per-corrupt");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let state = ckpt.join("state.bin");
    let mut bytes = std::fs::read(&state).unwrap();
    // Flip a byte at the tail: the priorities section is the last one.
    let last = bytes.len() - 3;
    bytes[last] ^= 0xFF;
    std::fs::write(&state, &bytes).unwrap();

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
    assert!(err.contains("priorities"), "must name the corrupt section: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_priorities_section_fails_with_clear_error() {
    let (dir, cfg) = write_proportional_checkpoint("per-truncated");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let state = ckpt.join("state.bin");
    let bytes = std::fs::read(&state).unwrap();
    // Cut a sliver off the end: only the tail section (the priorities
    // payload, several KB) loses bytes, so the error must name it.
    std::fs::write(&state, &bytes[..bytes.len() - 16]).unwrap();

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = format!("{:#}", coord.resume_from(&dir).unwrap_err());
    assert!(err.contains("truncated"), "unexpected error: {err}");
    assert!(err.contains("priorities"), "must name the truncated section: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn priorities_section_version_bump_is_rejected() {
    let (dir, cfg) = write_proportional_checkpoint("per-version");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let manifest = ckpt.join("manifest.json");
    let text = std::fs::read_to_string(&manifest).unwrap();
    // Bump the per-section version of exactly the priorities entry (keys
    // are sorted, so "version" follows "offset" within the entry).
    let at = text.find("\"name\":\"priorities\"").expect("priorities entry in manifest");
    let ver = text[at..].find("\"version\":1").expect("version field") + at;
    let mut patched = text.clone();
    patched.replace_range(ver..ver + "\"version\":1".len(), "\"version\":9");
    std::fs::write(&manifest, &patched).unwrap();

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(
        err.contains("priorities") && err.contains("version 9"),
        "unexpected error: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoints written before the replay-strategy layer lack its config
/// fingerprint keys; a default (uniform, n=1) run must still resume them —
/// they came off the identical machine — while a non-default strategy
/// config must still be refused.
#[test]
fn pre_strategy_checkpoints_resume_under_default_replay_config() {
    use tempo_dqn::util::json::Json;

    let (dir, cfg) = write_one_checkpoint("legacy-fp");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let manifest_path = ckpt.join("manifest.json");
    // Strip the post-§11 keys from the stored fingerprint, exactly what a
    // pre-upgrade checkpoint looks like.
    let mut manifest = Json::parse(&std::fs::read_to_string(&manifest_path).unwrap()).unwrap();
    {
        let Json::Obj(root) = &mut manifest else { panic!("manifest not an object") };
        let Some(Json::Obj(meta)) = root.get_mut("meta") else { panic!("no meta") };
        let Some(Json::Obj(config)) = meta.get_mut("config") else { panic!("no config") };
        for key in ["replay_strategy", "per_alpha", "per_beta0", "per_beta_anneal", "n_step"] {
            assert!(config.remove(key).is_some(), "fingerprint key {key} not present");
        }
    }
    std::fs::write(&manifest_path, manifest.to_string()).unwrap();

    // Default replay config: resumes.
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    assert_eq!(
        coord.resume_from(&dir).unwrap(),
        64,
        "pre-strategy checkpoint must resume under the default uniform/n=1 config"
    );

    // Non-default strategy config: refused with the key named.
    let mut other = cfg.clone();
    other.n_step = 3;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("n_step"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn uniform_checkpoint_has_no_priorities_section_and_proportional_requires_it() {
    // A uniform checkpoint must not grow the new section (old layout,
    // byte-compatible)...
    let (dir, cfg) = write_one_checkpoint("no-per-section");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let rdr = tempo_dqn::ckpt::CheckpointReader::open(&ckpt).unwrap();
    assert!(!rdr.has_section("priorities"), "uniform run must not write priorities");
    drop(rdr);
    // ...and a proportional run refuses it (fingerprint mismatch names
    // the strategy before any section is touched).
    let mut per = cfg;
    per.replay_strategy = tempo_dqn::config::ReplayStrategy::Proportional;
    let mut coord = Coordinator::new(per, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("replay_strategy"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);

    // A proportional checkpoint does carry it.
    let (dir, _cfg) = write_proportional_checkpoint("with-per-section");
    let ckpt = tempo_dqn::ckpt::latest_checkpoint(&dir).unwrap().unwrap();
    let rdr = tempo_dqn::ckpt::CheckpointReader::open(&ckpt).unwrap();
    assert!(rdr.has_section("priorities"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_without_checkpoint_is_a_clear_error() {
    let dir = tmpdir("empty");
    let cfg = base_cfg(ExecMode::Both, 2, 1, 1, 64);
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("no checkpoint found"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- run_for segmentation (the campaign runner's slicing primitive) ------

#[test]
fn run_for_slices_match_one_shot_run() {
    // Three run_for slices on one live machine == one run(): segmentation
    // at quiesce points is trajectory-neutral even without checkpoints.
    let cfg = base_cfg(ExecMode::Both, 2, 1, 1, 256);
    let one_shot = run_uninterrupted(&cfg);

    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    coord.run_for(Some(64)).unwrap();
    assert_eq!(coord.completed_steps(), 64);
    coord.run_for(Some(100)).unwrap(); // quantizes up to the 192 boundary
    assert_eq!(coord.completed_steps(), 192, "bounds align to C");
    coord.run_for(None).unwrap();
    assert_eq!(coord.completed_steps(), 256);
    assert_eq!(coord.state_digest().unwrap(), one_shot);
}

// ---- campaign runner ------------------------------------------------------

#[test]
fn round_robin_campaign_interleaves_resumes_and_skips_done_legs() {
    use tempo_dqn::campaign::Campaign;
    use tempo_dqn::config::toml::TomlDoc;

    let root = tmpdir("campaign");
    let toml = format!(
        "preset = \"smoke\"\n\
         [campaign]\nname = \"mini\"\nckpt_dir = \"{}\"\norder = \"round_robin\"\nslice = 64\n\
         [run]\ngame = \"seeker\"\nmode = \"both\"\nthreads = 2\n\
         [dqn]\ntotal_steps = 128\nprepopulate = 300\nreplay_capacity = 8000\n\
         target_update_period = 64\ntrain_period = 4\n\
         [leg.a_seeker]\nseed = 1\n\
         [leg.b_pong]\ngame = \"pong\"\nseed = 2\n",
        root.display()
    );
    let campaign = Campaign::from_toml(&TomlDoc::parse(&toml).unwrap()).unwrap();
    assert_eq!(campaign.legs.len(), 2);

    let mut log = Vec::new();
    let reports = campaign.run(&default_artifact_dir(), |l| log.push(l.to_string())).unwrap();
    assert_eq!(reports.len(), 2);
    for (r, leg) in reports.iter().zip(["a_seeker", "b_pong"]) {
        assert_eq!(r.id, leg);
        assert_eq!(r.steps, 128, "leg {leg} ran to completion");
    }
    // Round-robin with slice 64 < total 128 means every leg was resumed
    // from its own checkpoint at least once.
    assert!(
        log.iter().any(|l| l.contains("resumed a_seeker")),
        "expected a mid-campaign resume, log: {log:?}"
    );
    assert!(root.join("a_seeker/result.json").exists());
    assert!(root.join("b_pong/result.json").exists());

    // A second invocation skips both legs and reproduces the reports.
    let mut log2 = Vec::new();
    let again = campaign.run(&default_artifact_dir(), |l| log2.push(l.to_string())).unwrap();
    assert!(log2.iter().all(|l| l.contains("skipping")), "{log2:?}");
    assert_eq!(again[0].state_digest, reports[0].state_digest);
    assert_eq!(again[1].state_digest, reports[1].state_digest);
    let _ = std::fs::remove_dir_all(&root);
}

// ---- process boundary: the real binary, killed and resumed ---------------

#[test]
fn cli_resume_crosses_process_boundary_bit_exactly() {
    let dir = tmpdir("cli");
    let bin = env!("CARGO_BIN_EXE_tempo-dqn");
    let common = [
        "train", "--preset", "smoke", "--game", "seeker", "--threads", "2",
        "--seed", "9", "--target-period", "64", "--prepopulate", "300",
        "--replay-capacity", "8000", "--mode", "both",
    ];
    let digest_of = |extra: &[&str]| -> String {
        let out = std::process::Command::new(bin)
            .args(common)
            .args(extra)
            .output()
            .expect("spawn tempo-dqn");
        assert!(
            out.status.success(),
            "tempo-dqn failed: {}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout).to_string();
        stdout
            .lines()
            .find_map(|l| l.strip_prefix("state digest: ").map(str::to_string))
            .unwrap_or_else(|| panic!("no state digest in output:\n{stdout}"))
    };

    let ckpt = dir.to_string_lossy().into_owned();
    // Process 1: first half, checkpoint at its end.
    digest_of(&["--steps", "128", "--ckpt-dir", &ckpt, "--ckpt-period", "128"]);
    // Process 2: resume and finish.
    let resumed = digest_of(&[
        "--steps", "256", "--ckpt-dir", &ckpt, "--ckpt-period", "256", "--resume", &ckpt,
    ]);
    // Process 3: the uninterrupted reference.
    let reference = digest_of(&["--steps", "256"]);
    assert_eq!(resumed, reference, "cross-process resume diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
