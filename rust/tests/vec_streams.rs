//! W×B vectorized-stream acceptance tests (ISSUE 1).
//!
//! * all four exec modes run to completion with threads=2, envs_per_thread=4;
//! * synchronized modes issue exactly ONE device inference transaction per
//!   round of W×B steps (asserted via `Device` bus stats);
//! * stream semantics depend only on the global stream id, so any (W, B)
//!   factorization of the same stream count produces the identical
//!   trajectory in synchronized modes — in particular envs_per_thread=1
//!   reproduces the one-env-per-thread machine bit-for-bit.

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::{Coordinator, TrainResult};
use tempo_dqn::runtime::default_artifact_dir;

fn wxb_cfg(mode: ExecMode, threads: usize, envs_per_thread: usize, steps: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.mode = mode;
    cfg.threads = threads;
    cfg.envs_per_thread = envs_per_thread;
    cfg.total_steps = steps;
    cfg.game = "seeker".into();
    cfg.prepopulate = 300;
    cfg.replay_capacity = 16_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 21;
    cfg
}

fn run(cfg: ExperimentConfig) -> TrainResult {
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).expect("coordinator");
    coord.run().expect("run")
}

#[test]
fn all_modes_complete_with_wxb_streams() {
    for mode in ExecMode::ALL {
        let res = run(wxb_cfg(mode, 2, 4, 128));
        assert!(res.steps >= 128, "{mode:?}: steps {}", res.steps);
        assert!(res.trains > 0, "{mode:?}: no training happened");
        assert!(res.bus.transactions > 0, "{mode:?}: no device transactions");
    }
}

#[test]
fn sync_modes_issue_one_inference_transaction_per_round() {
    // In synchronized modes the ONLY device transactions are the one
    // batched inference per round of W×B steps plus one transaction per
    // minibatch update (target sync is a host-side buffer swap). With
    // eval disabled (smoke preset), the accounting must be exact.
    for mode in [ExecMode::Synchronized, ExecMode::Both] {
        let (w, b, steps) = (2usize, 4usize, 128u64);
        let res = run(wxb_cfg(mode, w, b, steps));
        let round = (w * b) as u64;
        assert_eq!(res.steps % round, 0, "{mode:?}: whole rounds only");
        let rounds = res.steps / round;
        assert_eq!(
            res.bus.transactions,
            rounds + res.trains,
            "{mode:?}: expected exactly {rounds} infer + {} train transactions, got {}",
            res.trains,
            res.bus.transactions
        );
    }
}

#[test]
fn wider_streams_cut_transactions_per_step() {
    // The B axis multiplies the per-transaction batch exactly like W does:
    // per-step infer transactions fall as 1/(W×B).
    let r_b1 = run(wxb_cfg(ExecMode::Synchronized, 2, 1, 96));
    let r_b4 = run(wxb_cfg(ExecMode::Synchronized, 2, 4, 96));
    let per_step_b1 = (r_b1.bus.transactions - r_b1.trains) as f64 / r_b1.steps as f64;
    let per_step_b4 = (r_b4.bus.transactions - r_b4.trains) as f64 / r_b4.steps as f64;
    assert!(
        per_step_b4 < per_step_b1 * 0.3,
        "B=4 should cut infer transactions ~4x: {per_step_b1:.3} vs {per_step_b4:.3}"
    );
}

#[test]
fn synchronized_trajectories_depend_only_on_stream_count() {
    // Stream `slot*B + j` derives its env seed, policy RNG stream, and
    // replay stream purely from its global id, and synchronized dispatch
    // assigns it step `round_base + slot*B + j` — so (W=4,B=1), (W=2,B=2)
    // and (W=1,B=4) are the SAME machine. In particular B=1 reproduces the
    // seed's one-env-per-thread behavior bit-for-bit.
    let a = run(wxb_cfg(ExecMode::Synchronized, 4, 1, 96));
    let b = run(wxb_cfg(ExecMode::Synchronized, 2, 2, 96));
    let c = run(wxb_cfg(ExecMode::Synchronized, 1, 4, 96));
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.steps, c.steps);
    assert_eq!(a.returns, b.returns, "W=4,B=1 vs W=2,B=2 trajectories diverged");
    assert_eq!(a.returns, c.returns, "W=4,B=1 vs W=1,B=4 trajectories diverged");
    assert_eq!(a.episodes, b.episodes);
    assert_eq!(a.episodes, c.episodes);
    // Fully inline training => identical update sequence and final theta.
    assert_eq!(a.trains, b.trains);
    assert_eq!(a.trains, c.trains);
}

#[test]
fn synchronized_wxb_runs_are_bit_deterministic() {
    let run_once = || {
        let mut coord =
            Coordinator::new(wxb_cfg(ExecMode::Synchronized, 2, 4, 96), &default_artifact_dir())
                .expect("coordinator");
        let res = coord.run().expect("run");
        let theta = coord.qnet().theta_host().expect("theta");
        (res.returns, res.losses, res.episodes, res.steps, theta)
    };
    let first = run_once();
    let second = run_once();
    assert_eq!(first.0, second.0, "returns must be identical across runs");
    assert_eq!(first.1, second.1, "losses must be identical across runs");
    assert_eq!(first.2, second.2);
    assert_eq!(first.3, second.3);
    assert_eq!(first.4, second.4, "final theta must be bit-identical");
}

#[test]
fn both_mode_wxb_acting_is_deterministic() {
    // Algorithm 1 with W×B streams: the trainer thread races only the
    // training count; acting reads theta_minus, which changes exclusively
    // at window barriers after the trainer caught up — so the acting
    // trajectory is still deterministic.
    let run_returns = || run(wxb_cfg(ExecMode::Both, 2, 4, 128)).returns;
    assert_eq!(run_returns(), run_returns(), "Both-mode trajectory diverged across runs");
}

#[test]
fn replay_spreads_over_all_wxb_streams() {
    // After a short run every stream must have received transitions
    // (prepopulation alone spreads N over W×B streams).
    let cfg = wxb_cfg(ExecMode::Synchronized, 2, 4, 64);
    let streams = cfg.streams();
    assert_eq!(streams, 8);
    let res = run(cfg);
    // 300 prepop + every executed step lands in replay (no staging in
    // synchronized-only mode).
    assert!(res.steps >= 64);
}
