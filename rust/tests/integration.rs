//! End-to-end integration tests: the full coordinator stack (environments,
//! replay, device runtime, all four execution modes) on the `tiny` network
//! with smoke-scale configs.

use std::sync::Arc;

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::metrics::GanttTrace;
use tempo_dqn::runtime::default_artifact_dir;

fn smoke_cfg(mode: ExecMode, threads: usize, steps: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.mode = mode;
    cfg.threads = threads;
    cfg.total_steps = steps;
    cfg.game = "seeker".into();
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 11;
    cfg
}

fn run(cfg: ExperimentConfig) -> tempo_dqn::coordinator::TrainResult {
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).expect("coordinator");
    coord.run().expect("run")
}

#[test]
fn standard_mode_completes_and_trains() {
    let res = run(smoke_cfg(ExecMode::Standard, 2, 128));
    assert!(res.steps >= 128, "steps {}", res.steps);
    // Standard: floor(t/F) updates gate acting at step t (127/4 = 31).
    assert!(res.trains >= 128 / 4 - 1, "trains {}", res.trains);
    assert!(res.bus.transactions > 0);
    assert!(!res.losses.is_empty());
}

#[test]
fn concurrent_mode_completes_with_target_syncs() {
    let res = run(smoke_cfg(ExecMode::Concurrent, 2, 192));
    assert!(res.steps >= 192);
    // C=64 -> at least 2 full windows -> >= 2 syncs and 16 batches/window.
    assert!(res.target_syncs >= 2, "syncs {}", res.target_syncs);
    assert!(res.trains >= 32, "trains {}", res.trains);
}

#[test]
fn synchronized_mode_batches_inference() {
    let res = run(smoke_cfg(ExecMode::Synchronized, 4, 128));
    assert_eq!(res.steps % 4, 0, "whole rounds only");
    assert!(res.steps >= 128);
    assert!(res.trains + 1 >= res.steps / 4, "trains {} steps {}", res.trains, res.steps);
    // Batched inference: far fewer transactions than steps.
    // rounds = steps / W, plus train transactions.
    let expected_infers = res.steps / 4;
    assert!(
        res.bus.transactions <= expected_infers + res.trains + 4,
        "transactions {} too high for SE (expect ~{} infers + {} trains)",
        res.bus.transactions, expected_infers, res.trains
    );
}

#[test]
fn both_mode_algorithm1_full_run() {
    let gantt = Arc::new(GanttTrace::new(100_000));
    let cfg = smoke_cfg(ExecMode::Both, 4, 256);
    let mut coord = Coordinator::new(cfg, &default_artifact_dir())
        .expect("coordinator")
        .with_gantt(gantt);
    let res = coord.run().expect("run");
    assert!(res.steps >= 256);
    assert!(res.target_syncs >= 3, "syncs {}", res.target_syncs);
    assert!(res.trains >= 48, "trains {}", res.trains);
    assert!(res.episodes > 0 || res.returns.is_empty());
}

#[test]
fn single_thread_works_in_all_modes() {
    for mode in [ExecMode::Standard, ExecMode::Concurrent, ExecMode::Synchronized, ExecMode::Both] {
        let res = run(smoke_cfg(mode, 1, 96));
        assert!(res.steps >= 96, "{mode:?}: steps {}", res.steps);
        assert!(res.trains > 0, "{mode:?}: no training happened");
    }
}

#[test]
fn sync_transactions_shrink_with_threads() {
    // The Figure 3 claim: SE's transaction count is independent of W
    // per-step (1/W per step), while async scales 1 per step.
    let r1 = run(smoke_cfg(ExecMode::Synchronized, 1, 96));
    let r4 = run(smoke_cfg(ExecMode::Synchronized, 4, 96));
    let per_step_1 = (r1.bus.transactions - r1.trains) as f64 / r1.steps as f64;
    let per_step_4 = (r4.bus.transactions - r4.trains) as f64 / r4.steps as f64;
    assert!(
        per_step_4 < per_step_1 * 0.5,
        "W=4 should cut infer transactions >=2x: {per_step_1:.2} vs {per_step_4:.2}"
    );
}

#[test]
fn concurrent_loss_curve_is_finite_and_learning_signal_exists() {
    let mut cfg = smoke_cfg(ExecMode::Both, 2, 384);
    cfg.game = "pong".into();
    let res = run(cfg);
    assert!(res.losses.iter().all(|(_, l)| l.is_finite()));
    assert!(res.losses.iter().any(|(_, l)| *l > 0.0));
    assert!(res.steps_per_sec > 0.0);
    assert!(!res.timers_report.is_empty());
}
