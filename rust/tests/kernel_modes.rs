//! The `kernel_mode` knob's divergence contract (rust/DESIGN.md §12).
//!
//! `deterministic` stays bit-pinned by the golden/equivalence suites; this
//! file pins the OTHER tier:
//!
//! * the fast kernels stay within a first-order reassociation bound of the
//!   deterministic kernels on ≥ 200 random shapes (the tiled==naive
//!   discipline from §8, relaxed from bitwise to bounded);
//! * a fast-mode end-to-end smoke run completes, trains with finite
//!   bounded losses, and is bit-identical run-to-run and across
//!   `learner_threads` (lane reordering is fixed by the kernels, not by
//!   thread count);
//! * checkpoints record the kernel mode — resuming a deterministic
//!   checkpoint under `fast` is refused (the trajectories diverge, so a
//!   bit-exact resume is impossible).

use std::path::PathBuf;

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;
use tempo_dqn::runtime::kernels::{
    matmul_a_bt_mode, matmul_acc_mode, matmul_at_b_acc_mode, KernelMode,
};
use tempo_dqn::util::rng::Rng;

/// Base seed: `TEMPO_PROPTEST_SEED` (CI pins it) or a fixed default.
fn base_seed() -> u64 {
    std::env::var("TEMPO_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x0C0F_FEE5)
}

/// Random activations with exact zeros (the post-ReLU sparsity the
/// kernels' skip paths key on).
fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| if rng.chance(0.25) { 0.0 } else { rng.f32() * 2.0 - 1.0 })
        .collect()
}

/// First-order reassociation bound for a length-`t` f32 reduction whose
/// terms have absolute sum `s`. Any two summation orders agree to within
/// O(t·ε·s); the factor 4 gives slack for the fused multiply ordering.
fn reassoc_tol(t: usize, s: f32) -> f32 {
    4.0 * t as f32 * f32::EPSILON * s + f32::MIN_POSITIVE
}

/// The acceptance property: on ≥ 200 random shapes, every element the
/// fast kernels produce is within the reassociation bound of the
/// deterministic (tiled) element. Shapes straddle the tile and lane
/// boundaries (k spans TILE_K = 128, n spans TILE_J = 64, both spill past
/// multiples of 8 and 4).
#[test]
fn prop_fast_kernels_bounded_divergence_on_200_shapes() {
    const SHAPES: usize = 220;
    for case in 0..SHAPES as u64 {
        let mut rng = Rng::new(base_seed() ^ (0xFA57_0000 + case));
        let m = 1 + rng.below_usize(16);
        let k = 1 + rng.below_usize(260);
        let n = 1 + rng.below_usize(96);
        let a = randvec(&mut rng, m * k);
        let b_kn = randvec(&mut rng, k * n);
        let b_mn = randvec(&mut rng, m * n);
        let b_nk = randvec(&mut rng, n * k);
        let seed_mn = randvec(&mut rng, m * n);
        let seed_kn = randvec(&mut rng, k * n);
        let ctx = |op: &str| format!("case {case} {op} m={m} k={k} n={n}");

        // out[m,n] (+)= a[m,k] @ b[k,n]
        let mut det = seed_mn.clone();
        let mut fast = seed_mn.clone();
        matmul_acc_mode(KernelMode::Deterministic, &a, &b_kn, &mut det, m, k, n);
        matmul_acc_mode(KernelMode::Fast, &a, &b_kn, &mut fast, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let s = seed_mn[i * n + j].abs()
                    + (0..k).map(|kk| (a[i * k + kk] * b_kn[kk * n + j]).abs()).sum::<f32>();
                let (d, f) = (det[i * n + j], fast[i * n + j]);
                assert!((d - f).abs() <= reassoc_tol(k + 1, s), "{} acc [{i},{j}]: {d} vs {f}", ctx("acc"));
            }
        }

        // out[k,n] (+)= aᵀ[k,m] @ b[m,n]
        let mut det = seed_kn.clone();
        let mut fast = seed_kn.clone();
        matmul_at_b_acc_mode(KernelMode::Deterministic, &a, &b_mn, &mut det, m, k, n);
        matmul_at_b_acc_mode(KernelMode::Fast, &a, &b_mn, &mut fast, m, k, n);
        for kk in 0..k {
            for j in 0..n {
                let s = seed_kn[kk * n + j].abs()
                    + (0..m).map(|i| (a[i * k + kk] * b_mn[i * n + j]).abs()).sum::<f32>();
                let (d, f) = (det[kk * n + j], fast[kk * n + j]);
                assert!((d - f).abs() <= reassoc_tol(m + 1, s), "{} [{kk},{j}]: {d} vs {f}", ctx("at_b"));
            }
        }

        // out[m,n] = a[m,k] @ bᵀ[n,k] (overwrite)
        let mut det = vec![0.0f32; m * n];
        let mut fast = vec![0.0f32; m * n];
        matmul_a_bt_mode(KernelMode::Deterministic, &a, &b_nk, &mut det, m, k, n);
        matmul_a_bt_mode(KernelMode::Fast, &a, &b_nk, &mut fast, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let s = (0..k).map(|kk| (a[i * k + kk] * b_nk[j * k + kk]).abs()).sum::<f32>();
                let (d, f) = (det[i * n + j], fast[i * n + j]);
                assert!((d - f).abs() <= reassoc_tol(k, s), "{} [{i},{j}]: {d} vs {f}", ctx("a_bt"));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fast-mode end-to-end smoke trajectory
// ---------------------------------------------------------------------------

fn fast_cfg(learner_threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.mode = ExecMode::Both;
    cfg.threads = 2;
    cfg.envs_per_thread = 2;
    cfg.learner_threads = learner_threads;
    cfg.prefetch_batches = 1;
    cfg.kernel_mode = KernelMode::Fast;
    cfg.total_steps = 192;
    cfg.game = "seeker".into();
    cfg.prepopulate = 300;
    cfg.replay_capacity = 16_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 33;
    cfg
}

/// Returns (returns, loss values, trains, final theta bits). Loss values
/// are order-deterministic in sync modes; steps are not compared.
fn run_trajectory(cfg: ExperimentConfig) -> (Vec<(u64, f64)>, Vec<u32>, u64, Vec<u32>) {
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).expect("coordinator");
    let res = coord.run().expect("run");
    for (step, loss) in &res.losses {
        assert!(loss.is_finite(), "non-finite loss {loss} at step {step}");
        assert!(*loss < 1e3, "exploding loss {loss} at step {step}");
    }
    assert!(res.trains > 0, "smoke run never trained");
    let losses = res.losses.iter().map(|(_, l)| l.to_bits()).collect();
    let theta = coord
        .qnet()
        .theta_host()
        .expect("theta")
        .iter()
        .map(|v| v.to_bits())
        .collect();
    (res.returns, losses, res.trains, theta)
}

#[test]
fn fast_mode_smoke_is_run_to_run_deterministic() {
    let first = run_trajectory(fast_cfg(1));
    let second = run_trajectory(fast_cfg(1));
    assert_eq!(first.0, second.0, "fast-mode returns not reproducible");
    assert_eq!(first.1, second.1, "fast-mode loss values not reproducible");
    assert_eq!(first.2, second.2, "fast-mode train counts not reproducible");
    assert_eq!(first.3, second.3, "fast-mode final theta not reproducible");
}

#[test]
fn fast_mode_smoke_is_invariant_across_learner_threads() {
    // The fast tier reorders accumulation into lanes, but the lane grouping
    // follows global sample order, never the shard layout — so like the
    // deterministic tier it is bit-identical at every pool width.
    let serial = run_trajectory(fast_cfg(1));
    let pooled = run_trajectory(fast_cfg(3));
    assert_eq!(serial.0, pooled.0, "returns diverged across pool widths");
    assert_eq!(serial.1, pooled.1, "loss values diverged across pool widths");
    assert_eq!(serial.2, pooled.2, "train counts diverged across pool widths");
    assert_eq!(serial.3, pooled.3, "final theta diverged across pool widths");
}

#[test]
fn fast_mode_diverges_from_deterministic_mode() {
    // Sanity that the knob actually switches kernels: the two tiers must
    // NOT be bit-identical end-to-end (if they were, the fast path would
    // not be running).
    let fast = run_trajectory(fast_cfg(1));
    let mut det_cfg = fast_cfg(1);
    det_cfg.kernel_mode = KernelMode::Deterministic;
    let det = run_trajectory(det_cfg);
    assert_ne!(fast.3, det.3, "fast and deterministic produced identical theta bits");
}

// ---------------------------------------------------------------------------
// Checkpoint compatibility
// ---------------------------------------------------------------------------

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-kmode-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn resume_refuses_kernel_mode_mismatch() {
    let dir = tmpdir("mismatch");
    let mut cfg = fast_cfg(1);
    cfg.kernel_mode = KernelMode::Deterministic;
    cfg.total_steps = 64;
    cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    cfg.ckpt_period = 64;
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    drop(coord);

    // Same config under `fast` must be refused...
    let mut fast = cfg.clone();
    fast.kernel_mode = KernelMode::Fast;
    let mut coord = Coordinator::new(fast, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("kernel_mode"), "unexpected error: {err}");

    // ...while the matching mode resumes fine.
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).unwrap();
    assert_eq!(coord.resume_from(&dir).unwrap(), 64);
    let _ = std::fs::remove_dir_all(&dir);
}
