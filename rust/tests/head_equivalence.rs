//! Cross-mode determinism matrix for the dueling and C51 heads
//! (rust/DESIGN.md §16).
//!
//! Three claims, pinned end-to-end through `Coordinator::state_digest`:
//!
//! 1. **dqn is untouched.** The default head routes through literally the
//!    pre-head code path (`tests/strategy_equivalence.rs` and
//!    `tests/runtime_golden.rs` pin its digests); here we only assert the
//!    new heads actually *change* the trajectory — they are not aliases.
//!
//! 2. **The new heads inherit the determinism contract.** For each head,
//!    the digest is bit-identical across learner_threads {1,4} ×
//!    prefetch {0,2} × all four exec modes, and across kill-and-resume
//!    mid-run — the same matrix every other trajectory-affecting feature
//!    must pass. This works because the head forward/backward passes fold
//!    in fixed ascending order at any pool width (runtime/heads.rs).
//!
//! 3. **Identity is head-qualified.** A checkpoint trained under one head
//!    (or one C51 support) refuses to resume under another, naming the
//!    knob — the config fingerprint carries head/atoms/v_min/v_max.
//!
//! C51 runs `atoms = 11` here: same code path as the paper's 51, a third
//! of the tail FLOPs, and it pins that non-default supports thread through
//! config → engine → checkpoint.

use std::path::PathBuf;

use tempo_dqn::config::{ExecMode, ExperimentConfig, HeadKind};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;

fn cfg(
    head: HeadKind,
    mode: ExecMode,
    learner_threads: usize,
    prefetch_batches: usize,
) -> ExperimentConfig {
    let (threads, b) = match mode {
        ExecMode::Standard | ExecMode::Concurrent => (1, 2),
        ExecMode::Synchronized | ExecMode::Both => (2, 2),
    };
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.game = "seeker".into();
    cfg.mode = mode;
    cfg.threads = threads;
    cfg.envs_per_thread = b;
    cfg.learner_threads = learner_threads;
    cfg.prefetch_batches = prefetch_batches;
    cfg.head = head;
    if head == HeadKind::C51 {
        cfg.atoms = 11;
        cfg.v_min = -2.0;
        cfg.v_max = 2.0;
    }
    cfg.total_steps = 192;
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.seed = 77;
    cfg
}

fn digest(cfg: &ExperimentConfig) -> u64 {
    let mut coord = Coordinator::new(cfg.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    coord.state_digest().unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-head-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Kill-and-resume: run to `cut` with a checkpoint, rebuild a fresh
/// coordinator, resume, finish; digest must match the uninterrupted run.
fn digest_resumed(cfg: &ExperimentConfig, cut: u64, tag: &str) -> u64 {
    let dir = tmpdir(tag);
    let mut half = cfg.clone();
    half.total_steps = cut;
    half.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    half.ckpt_period = cut;
    let mut first = Coordinator::new(half, &default_artifact_dir()).unwrap();
    first.run().unwrap();
    drop(first);

    let mut full = cfg.clone();
    full.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    full.ckpt_period = cfg.total_steps;
    let mut second = Coordinator::new(full, &default_artifact_dir()).unwrap();
    assert_eq!(second.resume_from(&dir).unwrap(), cut, "{tag}: checkpoint not at the cut");
    second.run().unwrap();
    let d = second.state_digest().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    d
}

/// learner_threads {1,4} × prefetch {0,2}, per exec mode, one head.
fn assert_matrix_invariant(head: HeadKind) {
    for mode in ExecMode::ALL {
        let reference = digest(&cfg(head, mode, 1, 0));
        for (lt, pf) in [(1usize, 2usize), (4, 0), (4, 2)] {
            assert_eq!(
                reference,
                digest(&cfg(head, mode, lt, pf)),
                "{}/{}: learner_threads={lt} prefetch={pf} moved the trajectory",
                head.name(),
                mode.name()
            );
        }
    }
}

#[test]
fn dueling_digest_invariant_across_learner_threads_and_prefetch() {
    assert_matrix_invariant(HeadKind::Dueling);
}

#[test]
fn c51_digest_invariant_across_learner_threads_and_prefetch() {
    assert_matrix_invariant(HeadKind::C51);
}

#[test]
fn dueling_kill_and_resume_is_bit_exact_per_mode() {
    for mode in ExecMode::ALL {
        let base = cfg(HeadKind::Dueling, mode, 1, 0);
        let cut = match mode {
            ExecMode::Standard => 64,
            _ => 128,
        };
        assert_eq!(
            digest(&base),
            digest_resumed(&base, cut, &format!("duel-{}", mode.name())),
            "dueling/{}: resumed trajectory diverged",
            mode.name()
        );
    }
}

#[test]
fn c51_kill_and_resume_is_bit_exact_per_mode() {
    for mode in ExecMode::ALL {
        let base = cfg(HeadKind::C51, mode, 1, 0);
        let cut = match mode {
            ExecMode::Standard => 64,
            _ => 128,
        };
        assert_eq!(
            digest(&base),
            digest_resumed(&base, cut, &format!("c51-{}", mode.name())),
            "c51/{}: resumed trajectory diverged",
            mode.name()
        );
    }
}

/// The heads are real alternatives: each produces a distinct trajectory
/// from dqn and from each other, and the C51 support parameters matter.
#[test]
fn heads_produce_distinct_trajectories() {
    let dqn = digest(&cfg(HeadKind::Dqn, ExecMode::Both, 1, 0));
    let duel = digest(&cfg(HeadKind::Dueling, ExecMode::Both, 1, 0));
    let c51 = digest(&cfg(HeadKind::C51, ExecMode::Both, 1, 0));
    assert_ne!(dqn, duel, "dueling trajectory identical to dqn");
    assert_ne!(dqn, c51, "c51 trajectory identical to dqn");
    assert_ne!(duel, c51, "c51 trajectory identical to dueling");

    let mut wide = cfg(HeadKind::C51, ExecMode::Both, 1, 0);
    wide.v_min = -4.0;
    wide.v_max = 4.0;
    assert_ne!(c51, digest(&wide), "the C51 support has no effect on the trajectory");
}

/// Resume refuses a checkpoint trained under a different head (or a
/// different C51 support), naming the knob.
#[test]
fn head_mismatched_checkpoints_refuse_resume_by_name() {
    let dir = tmpdir("mismatch");
    let mut base = cfg(HeadKind::C51, ExecMode::Both, 1, 0);
    base.total_steps = 64;
    base.ckpt_dir = Some(dir.to_string_lossy().into_owned());
    base.ckpt_period = 64;
    let mut coord = Coordinator::new(base.clone(), &default_artifact_dir()).unwrap();
    coord.run().unwrap();
    drop(coord);

    let mut other = base.clone();
    other.head = HeadKind::Dueling;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("head"), "must name the head knob: {err}");

    let mut other = base.clone();
    other.atoms = 21;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("atoms"), "must name the atoms knob: {err}");

    let mut other = base.clone();
    other.v_max = 3.0;
    let mut coord = Coordinator::new(other, &default_artifact_dir()).unwrap();
    let err = coord.resume_from(&dir).unwrap_err().to_string();
    assert!(err.contains("v_max"), "must name the support knob: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
