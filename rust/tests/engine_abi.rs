//! Engine-ABI conformance suite (rust/DESIGN.md §16).
//!
//! The engine boundary is a *named* schema, not a positional tensor list;
//! this suite pins the contract for every head variant:
//!
//! * every head × every builtin entry derives a named schema whose fields
//!   cross-check the (head-adjusted) manifest declaration;
//! * mis-shaped, missing, and extra transaction inputs are refused at the
//!   engine with the entry AND field named — including a parameter vector
//!   of the *wrong head's* length;
//! * checkpoint identity is head-qualified (`{config}+{head}`): a
//!   checkpoint written under one head is refused by name when offered to
//!   a run using another, in both directions.
//!
//! (The serving daemon's head-mismatch refusal rides in `tests/serve.rs`;
//! the fleet handshake's rides in `coordinator/fleet.rs` unit tests — both
//! flow through the same head-qualified identity pinned here.)

use std::sync::Arc;

use tempo_dqn::ckpt::{ByteReader, ByteWriter, Snapshot};
use tempo_dqn::runtime::{
    Device, EntryOp, EntrySchema, Head, Manifest, QNet, QNetSnapshot, TensorView,
};

fn heads() -> [Head; 3] {
    [
        Head::Dqn,
        Head::Dueling,
        Head::C51 { atoms: 51, v_min: -10.0, v_max: 10.0 },
    ]
}

#[test]
fn every_head_derives_named_schemas_for_every_builtin_entry() {
    let m = Manifest::builtin();
    for name in ["tiny", "small", "nature"] {
        let base_p = m.config(name).unwrap().param_count;
        for head in heads() {
            let spec = m.config_with_head(name, head).unwrap();
            assert!(!spec.entries.is_empty());
            if !matches!(head, Head::Dqn) {
                assert_ne!(
                    spec.param_count, base_p,
                    "{name}/{}: head must change the flat parameter count",
                    head.tag()
                );
            }
            for (entry_name, entry) in &spec.entries {
                let schema = EntrySchema::derive(&spec, entry_name)
                    .unwrap_or_else(|e| panic!("{name}/{entry_name} under {head:?}: {e:#}"));
                // Load-time half of the ABI: the manifest's declared inputs
                // match the schema field for field.
                schema.validate_manifest_entry(entry).unwrap();
                assert_eq!(schema.head, spec.head);
                assert_eq!(schema.inputs[0].name, "params");
                assert_eq!(schema.inputs[0].shape, vec![spec.param_count]);
                match schema.op {
                    EntryOp::Infer => {
                        assert_eq!(schema.inputs.len(), 2);
                        assert!(schema.optional_inputs.is_empty());
                        assert_eq!(schema.outputs[0].name, "q");
                        // Every head — C51 included — emits [B, A] Q-rows.
                        assert_eq!(schema.outputs[0].shape, vec![schema.batch, spec.actions]);
                    }
                    EntryOp::Train { .. } => {
                        assert_eq!(schema.inputs.len(), 10);
                        assert_eq!(schema.optional_inputs.len(), 2);
                        assert_eq!(schema.outputs.len(), 5);
                        assert_eq!(schema.outputs[3].name, "loss");
                        assert_eq!(schema.outputs[4].shape, vec![schema.batch]);
                    }
                }
            }
        }
    }
}

#[test]
fn engine_refuses_misshaped_transactions_by_entry_and_field_for_every_head() {
    let m = Manifest::builtin();
    for head in heads() {
        let spec = m.config_with_head("tiny", head).unwrap();
        let device = Device::cpu().unwrap();
        let key = format!("{}/infer_b2", spec.runtime_name());
        device.load_entry(&key, &spec, "infer_b2").unwrap();
        let [h, w, c] = spec.frame;
        let p = vec![0.0f32; spec.param_count];
        let st = vec![0u8; 2 * h * w * c];

        // Missing input: refused naming the entry and the absent field.
        let err = device
            .execute(&key, &[TensorView::f32(&p, &[spec.param_count])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("infer_b2") && err.contains("states"), "{head:?}: {err}");

        // A parameter vector of the wrong length — e.g. another head's
        // layout — is refused by field name, not executed against garbage.
        let wrong = vec![0.0f32; spec.param_count + 1];
        let err = device
            .execute(
                &key,
                &[
                    TensorView::f32(&wrong, &[spec.param_count + 1]),
                    TensorView::u8(&st, &[2, h, w, c]),
                ],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("params") && err.contains("shape"), "{head:?}: {err}");

        // Wrong dtype: states as f32 instead of u8.
        let stf = vec![0.0f32; 2 * h * w * c];
        let err = device
            .execute(
                &key,
                &[
                    TensorView::f32(&p, &[spec.param_count]),
                    TensorView::f32(&stf, &[2, h, w, c]),
                ],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("states") && err.contains("u8"), "{head:?}: {err}");

        // Extra trailing input on an entry with no optional fields.
        let err = device
            .execute(
                &key,
                &[
                    TensorView::f32(&p, &[spec.param_count]),
                    TensorView::u8(&st, &[2, h, w, c]),
                    TensorView::u8(&st, &[2, h, w, c]),
                ],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("infer_b2"), "{head:?}: {err}");

        // The well-formed transaction executes and yields [2, A] Q-rows.
        let out = device
            .execute(
                &key,
                &[
                    TensorView::f32(&p, &[spec.param_count]),
                    TensorView::u8(&st, &[2, h, w, c]),
                ],
            )
            .unwrap();
        let q = out.into_iter().next().unwrap().into_f32("q").unwrap();
        assert_eq!(q.len(), 2 * spec.actions, "{head:?}");
        assert!(q.iter().all(|v| v.is_finite()), "{head:?}");
    }
}

#[test]
fn checkpoints_are_refused_across_heads_by_name() {
    let m = Manifest::builtin();
    let all = [
        Head::Dqn,
        Head::Dueling,
        Head::C51 { atoms: 51, v_min: -10.0, v_max: 10.0 },
        // Different support parameters are a different network identity.
        Head::C51 { atoms: 21, v_min: -5.0, v_max: 5.0 },
    ];
    let nets: Vec<QNet> = all
        .iter()
        .map(|&head| {
            let device = Arc::new(Device::cpu().unwrap());
            QNet::load_with_head(device, &m, "tiny", false, 32, head).unwrap()
        })
        .collect();
    for (i, from) in nets.iter().enumerate() {
        let mut w = ByteWriter::new();
        QNetSnapshot(from).save(&mut w);
        let bytes = w.into_bytes();
        for (j, to) in nets.iter().enumerate() {
            let mut r = ByteReader::new(&bytes);
            let mut snap = QNetSnapshot(to);
            if i == j {
                snap.load(&mut r).unwrap_or_else(|e| {
                    panic!("{}: same-head restore must succeed: {e:#}", all[i].tag())
                });
            } else {
                let err = snap.load(&mut r).unwrap_err().to_string();
                let (fname, tname) = (from.spec().runtime_name(), to.spec().runtime_name());
                assert!(
                    err.contains(&fname) && err.contains(&tname),
                    "{} -> {}: refusal must name both identities: {err}",
                    fname,
                    tname
                );
            }
        }
    }
}
