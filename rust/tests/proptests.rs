//! Property-based tests (proptest is unavailable offline; this file uses
//! seeded randomized generation with many iterations per property —
//! failures print the seed for reproduction).
//!
//! Properties cover the determinism invariants from rust/DESIGN.md §7 plus the
//! from-scratch substrates (JSON, RNG, replay chaining, DES bounds).

use tempo_dqn::config::EpsSchedule;
use tempo_dqn::config::ExecMode;
use tempo_dqn::hwsim::{simulate, CostModel, SimRun};
use tempo_dqn::metrics::{GanttTrace, Phase};
use tempo_dqn::replay::ReplayMemory;
use tempo_dqn::runtime::kernels::{
    col2im_sample, conv2d_forward, conv2d_forward_fast, conv2d_input_grad,
    conv2d_input_grad_fast, conv2d_weight_grad_chunk, conv2d_weight_grad_chunk_fast,
    im2col_sample, matmul_a_bt_fast, matmul_a_bt_tiled, matmul_acc_fast, matmul_acc_tiled,
    matmul_at_b_acc_fast, matmul_at_b_acc_tiled,
};
use tempo_dqn::runtime::TrainBatch;
use tempo_dqn::util::json::Json;
use tempo_dqn::util::rng::Rng;

const CASES: u64 = 60;

/// Base seed: `TEMPO_PROPTEST_SEED` (CI pins it) or a fixed default.
fn base_seed() -> u64 {
    std::env::var("TEMPO_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x0C0F_FEE5)
}

// ---------------------------------------------------------------------------
// Replay memory vs a naive flat-store reference model
// ---------------------------------------------------------------------------

/// Naive reference: stores every transition in full, stacking by scanning
/// back through the episode.
struct NaiveReplay {
    frames: Vec<Vec<u8>>,
    actions: Vec<u8>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    starts: Vec<bool>,
    stack: usize,
}

impl NaiveReplay {
    fn state_at(&self, i: usize) -> Vec<u8> {
        // Channel-last interleave of the `stack` frames ending at i,
        // replicating past episode starts.
        let mut slots = vec![0usize; self.stack];
        let mut cur = i;
        for c in (0..self.stack).rev() {
            slots[c] = cur;
            if cur > 0 && !self.starts[cur] {
                cur -= 1;
            }
        }
        let fs = self.frames[0].len();
        let mut out = vec![0u8; fs * self.stack];
        for (c, &slot) in slots.iter().enumerate() {
            for (p, &v) in self.frames[slot].iter().enumerate() {
                out[p * self.stack + c] = v;
            }
        }
        out
    }
}

#[test]
fn prop_replay_stacks_match_naive_model() {
    const FS: usize = 8;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let cap = 32 + rng.below_usize(64);
        let mut replay = ReplayMemory::new(cap, 1, FS, 4, seed).unwrap();
        let mut naive = NaiveReplay {
            frames: Vec::new(),
            actions: Vec::new(),
            rewards: Vec::new(),
            dones: Vec::new(),
            starts: Vec::new(),
            stack: 4,
        };
        let n = 10 + rng.below_usize(cap - 10); // within capacity: naive has no ring
        let mut start = true;
        for t in 0..n {
            let frame = vec![(t + 1) as u8; FS]; // unique per slot (n <= cap < 256)
            let action = rng.below(6) as u8;
            let reward = rng.f32() - 0.5;
            let done = rng.chance(0.1);
            replay.push(0, &frame, action, reward, done, start);
            naive.frames.push(frame);
            naive.actions.push(action);
            naive.rewards.push(reward);
            naive.dones.push(done);
            naive.starts.push(start);
            start = done;
        }
        // Compare the newest reconstructable state.
        let got = replay.latest_state(0).unwrap();
        let want = naive.state_at(n - 1);
        assert_eq!(got, want, "seed {seed}: latest_state mismatch");

        // Sampled minibatches must agree with the naive model everywhere.
        if replay.sampleable() > 0 {
            let mut batch = TrainBatch::default();
            replay.sample(16, &mut batch).unwrap();
            let sb = FS * 4;
            for b in 0..16 {
                let s = &batch.states[b * sb..(b + 1) * sb];
                // Identify the slot by its (unique) newest frame value.
                let newest = s[3] as usize;
                let idx = newest - 1;
                assert_eq!(s, &naive.state_at(idx)[..], "seed {seed}: state b={b}");
                assert_eq!(batch.actions[b] as u8, naive.actions[idx], "seed {seed}");
                assert_eq!(batch.rewards[b], naive.rewards[idx], "seed {seed}");
                assert_eq!(batch.dones[b] == 1.0, naive.dones[idx], "seed {seed}");
                let ns = &batch.next_states[b * sb..(b + 1) * sb];
                if naive.dones[idx] {
                    assert_eq!(ns, s, "seed {seed}: done successor must be masked");
                } else {
                    assert_eq!(ns, &naive.state_at(idx + 1)[..], "seed {seed}: next state");
                }
            }
        }
    }
}

#[test]
fn prop_replay_ring_never_returns_overwritten_frames() {
    const FS: usize = 4;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let cap = 16 + rng.below_usize(32);
        let mut replay = ReplayMemory::new(cap, 1, FS, 4, seed).unwrap();
        let n = cap * 2 + rng.below_usize(cap * 2);
        for t in 0..n {
            replay.push(0, &[(t % 251) as u8; FS], 0, 0.0, rng.chance(0.05), t == 0);
        }
        let oldest_live = n - cap; // logical index of the oldest surviving frame
        let mut batch = TrainBatch::default();
        replay.sample(32, &mut batch).unwrap();
        for b in 0..32 {
            let newest = batch.states[b * FS * 4 + 3] as usize;
            // The newest frame of any sampled state must be a live slot.
            let found = (oldest_live..n).any(|t| t % 251 == newest);
            assert!(found, "seed {seed}: stale frame {newest} sampled");
        }
    }
}

// ---------------------------------------------------------------------------
// im2col / col2im adjoint consistency (rust/DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Random VALID-padding conv geometry (im2col has no padding parameter —
/// the nets only use VALID convolutions).
fn conv_shape(rng: &mut Rng) -> (usize, usize, usize, usize, usize) {
    let kernel = 1 + rng.below_usize(4);
    let stride = 1 + rng.below_usize(3);
    let h = kernel + rng.below_usize(8);
    let w = kernel + rng.below_usize(8);
    let c = 1 + rng.below_usize(4);
    (h, w, c, kernel, stride)
}

/// col2im is the transpose of im2col: `⟨im2col(x), Y⟩ == ⟨x, col2im(Y)⟩`
/// for every x, Y, and geometry. (The backward pass depends on exactly
/// this; until now it was only exercised through finite differences.)
#[test]
fn prop_col2im_is_adjoint_of_im2col() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xAD70 + case));
        let (h, w, c, kernel, stride) = conv_shape(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let kdim = kernel * kernel * c;
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let y: Vec<f32> = (0..oh * ow * kdim).map(|_| rng.f32() * 2.0 - 1.0).collect();

        let mut patches = vec![0.0f32; oh * ow * kdim];
        im2col_sample(&x, h, w, c, kernel, stride, &mut patches);
        let mut dx = vec![0.0f32; h * w * c];
        col2im_sample(&y, h, w, c, kernel, stride, &mut dx);

        // Both inner products sum the same set of x_i * y_j terms; compare
        // in f64 with a tolerance for col2im's f32 scatter-add rounding.
        let lhs: f64 = patches.iter().zip(&y).map(|(&p, &q)| p as f64 * q as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(&p, &q)| p as f64 * q as f64).sum();
        let scale: f64 = patches
            .iter()
            .zip(&y)
            .map(|(&p, &q)| (p as f64 * q as f64).abs())
            .sum::<f64>()
            .max(1e-12);
        assert!(
            (lhs - rhs).abs() / scale < 1e-5,
            "case {case} (h={h} w={w} c={c} k={kernel} s={stride}): \
             <im2col(x), y> = {lhs} vs <x, col2im(y)> = {rhs}"
        );
    }
}

/// col2im of all-ones patch gradients writes each pixel's patch-coverage
/// count — checked against a naive window-membership count (exact in f32:
/// small integer sums).
#[test]
fn prop_col2im_of_ones_counts_patch_coverage() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xC072 + case));
        let (h, w, c, kernel, stride) = conv_shape(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let ones = vec![1.0f32; oh * ow * kernel * kernel * c];
        let mut dx = vec![0.0f32; h * w * c];
        col2im_sample(&ones, h, w, c, kernel, stride, &mut dx);
        for py in 0..h {
            for px in 0..w {
                let mut count = 0usize;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let (y0, x0) = (oy * stride, ox * stride);
                        if py >= y0 && py < y0 + kernel && px >= x0 && px < x0 + kernel {
                            count += 1;
                        }
                    }
                }
                for ch in 0..c {
                    assert_eq!(
                        dx[(py * w + px) * c + ch],
                        count as f32,
                        "case {case} (h={h} w={w} c={c} k={kernel} s={stride}) pixel ({py},{px},{ch})"
                    );
                }
            }
        }
    }
}

/// im2col gathers exactly the naive window elements — and fully overwrites
/// its output (no stale data survives; the scratch-buffer recycling in
/// `runtime/native.rs` relies on this).
#[test]
fn prop_im2col_matches_naive_gather() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0x17C0 + case));
        let (h, w, c, kernel, stride) = conv_shape(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let kdim = kernel * kernel * c;
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.f32() * 2.0 - 1.0).collect();
        // NaN sentinel: any slot im2col fails to overwrite fails the test.
        let mut patches = vec![f32::NAN; oh * ow * kdim];
        im2col_sample(&x, h, w, c, kernel, stride, &mut patches);
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kernel {
                    for kx in 0..kernel {
                        for ch in 0..c {
                            let got =
                                patches[(oy * ow + ox) * kdim + (ky * kernel + kx) * c + ch];
                            let want = x[((oy * stride + ky) * w + ox * stride + kx) * c + ch];
                            assert_eq!(
                                got.to_bits(),
                                want.to_bits(),
                                "case {case} (h={h} w={w} c={c} k={kernel} s={stride}) \
                                 patch ({oy},{ox}) offset ({ky},{kx},{ch})"
                            );
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Patch-free convolution vs the im2col pipeline (rust/DESIGN.md §13)
// ---------------------------------------------------------------------------

/// Wider geometry generator than [`conv_shape`]: filter counts straddle the
/// 8-lane boundary and kdim straddles the rank-4 blocking, so both the
/// vector bodies and the serial tails of the direct kernels are exercised.
fn conv_shape_wide(rng: &mut Rng) -> (usize, usize, usize, usize, usize, usize) {
    let kernel = 1 + rng.below_usize(5);
    let stride = 1 + rng.below_usize(3);
    let h = kernel + rng.below_usize(10);
    let w = kernel + rng.below_usize(10);
    let c = 1 + rng.below_usize(12);
    let filters = 1 + rng.below_usize(70);
    (h, w, c, kernel, stride, filters)
}

/// Activations with exact zeros mixed in (the post-ReLU sparsity skips in
/// both tiers fire only on exact zeros).
fn sparse_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| if rng.chance(0.25) { 0.0 } else { rng.f32() * 4.0 - 2.0 })
        .collect()
}

fn assert_bits(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}[{i}]: {x} vs {y}");
    }
}

/// Deterministic tier: the patch-free kernels must be **bitwise identical**
/// to im2col + the tiled matmuls for every op on random geometries — this
/// is the contract that lets the engine drop the patch buffers without
/// moving the default trajectory. Weight gradients are additionally
/// re-assembled from a random row split (Phase B partitions never align
/// with kernel-row boundaries).
#[test]
fn prop_direct_conv_det_bitwise_equals_im2col_pipeline() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xD12EC7 + case));
        let (h, w, c, kernel, stride, filters) = conv_shape_wide(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let (nrow, kdim) = (oh * ow, kernel * kernel * c);
        let ctx = format!("case {case} (h={h} w={w} c={c} k={kernel} s={stride} f={filters})");
        let x = sparse_vec(&mut rng, h * w * c);
        let wmat = sparse_vec(&mut rng, kdim * filters);
        let dy = sparse_vec(&mut rng, nrow * filters);
        let mut patches = vec![0.0f32; nrow * kdim];
        im2col_sample(&x, h, w, c, kernel, stride, &mut patches);

        let mut y_ref = vec![0.0f32; nrow * filters];
        matmul_acc_tiled(&patches, &wmat, &mut y_ref, nrow, kdim, filters);
        let mut y = vec![0.0f32; nrow * filters];
        conv2d_forward(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
        assert_bits(&y_ref, &y, &format!("{ctx} fwd"));

        let mut dpatches = vec![0.0f32; nrow * kdim];
        matmul_a_bt_tiled(&dy, &wmat, &mut dpatches, nrow, filters, kdim);
        let mut dx_ref = vec![0.0f32; h * w * c];
        col2im_sample(&dpatches, h, w, c, kernel, stride, &mut dx_ref);
        let mut dx = vec![0.0f32; h * w * c];
        conv2d_input_grad(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
        assert_bits(&dx_ref, &dx, &format!("{ctx} igrad"));

        let mut dw_ref = vec![0.0f32; kdim * filters];
        matmul_at_b_acc_tiled(&patches, &dy, &mut dw_ref, nrow, kdim, filters);
        let split = rng.below_usize(kdim + 1);
        let mut dw = vec![0.0f32; kdim * filters];
        for (lo, hi) in [(0, split), (split, kdim)] {
            conv2d_weight_grad_chunk(
                &x,
                &dy,
                &mut dw[lo * filters..hi * filters],
                lo,
                hi,
                h,
                w,
                c,
                kernel,
                stride,
                filters,
            );
        }
        assert_bits(&dw_ref, &dw, &format!("{ctx} wgrad split@{split}"));
    }
}

/// Fast tier: the direct kernels must be bitwise identical to im2col + the
/// fast (lane-reordered) matmuls — same rank-4 blocks, same dot8 trees,
/// just no patch matrix.
#[test]
fn prop_direct_conv_fast_bitwise_equals_im2col_fast_pipeline() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xFA57D1 + case));
        let (h, w, c, kernel, stride, filters) = conv_shape_wide(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let (nrow, kdim) = (oh * ow, kernel * kernel * c);
        let ctx = format!("case {case} (h={h} w={w} c={c} k={kernel} s={stride} f={filters})");
        let x = sparse_vec(&mut rng, h * w * c);
        let wmat = sparse_vec(&mut rng, kdim * filters);
        let dy = sparse_vec(&mut rng, nrow * filters);
        let mut patches = vec![0.0f32; nrow * kdim];
        im2col_sample(&x, h, w, c, kernel, stride, &mut patches);

        let mut y_ref = vec![0.0f32; nrow * filters];
        matmul_acc_fast(&patches, &wmat, &mut y_ref, nrow, kdim, filters);
        let mut y = vec![0.0f32; nrow * filters];
        conv2d_forward_fast(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
        assert_bits(&y_ref, &y, &format!("{ctx} fwd fast"));

        let mut dpatches = vec![0.0f32; nrow * kdim];
        matmul_a_bt_fast(&dy, &wmat, &mut dpatches, nrow, filters, kdim);
        let mut dx_ref = vec![0.0f32; h * w * c];
        col2im_sample(&dpatches, h, w, c, kernel, stride, &mut dx_ref);
        let mut dx = vec![0.0f32; h * w * c];
        conv2d_input_grad_fast(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
        assert_bits(&dx_ref, &dx, &format!("{ctx} igrad fast"));

        let mut dw_ref = vec![0.0f32; kdim * filters];
        matmul_at_b_acc_fast(&patches, &dy, &mut dw_ref, nrow, kdim, filters);
        let split = rng.below_usize(kdim + 1);
        let mut dw = vec![0.0f32; kdim * filters];
        for (lo, hi) in [(0, split), (split, kdim)] {
            conv2d_weight_grad_chunk_fast(
                &x,
                &dy,
                &mut dw[lo * filters..hi * filters],
                lo,
                hi,
                h,
                w,
                c,
                kernel,
                stride,
                filters,
            );
        }
        assert_bits(&dw_ref, &dw, &format!("{ctx} wgrad fast split@{split}"));
    }
}

/// First-order reassociation bound for a length-`t` f32 reduction with
/// absolute term sum `s` (same constant as the matmul divergence tests).
fn reassoc_tol(t: usize, s: f32) -> f32 {
    4.0 * (t as f32) * f32::EPSILON * s + f32::MIN_POSITIVE
}

/// Fast vs deterministic direct kernels obey the §12 bounded-divergence
/// contract per output element: `|fast − det| ≤ c·t·ε·Σ|termᵢ|` where `t`
/// is the element's reduction length.
#[test]
fn prop_direct_conv_fast_vs_det_bounded_divergence() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xB0D1_7E57 + case));
        let (h, w, c, kernel, stride, filters) = conv_shape_wide(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let (nrow, kdim) = (oh * ow, kernel * kernel * c);
        let ctx = format!("case {case} (h={h} w={w} c={c} k={kernel} s={stride} f={filters})");
        let x = sparse_vec(&mut rng, h * w * c);
        let wmat = sparse_vec(&mut rng, kdim * filters);
        let dy = sparse_vec(&mut rng, nrow * filters);
        let mut patches = vec![0.0f32; nrow * kdim];
        im2col_sample(&x, h, w, c, kernel, stride, &mut patches);

        // Forward: reduction length kdim per output element.
        let mut y_det = vec![0.0f32; nrow * filters];
        conv2d_forward(&x, &wmat, &mut y_det, h, w, c, kernel, stride, filters);
        let mut y_fast = vec![0.0f32; nrow * filters];
        conv2d_forward_fast(&x, &wmat, &mut y_fast, h, w, c, kernel, stride, filters);
        for row in 0..nrow {
            for f in 0..filters {
                let mut s = 0.0f32;
                for kk in 0..kdim {
                    s += (patches[row * kdim + kk] * wmat[kk * filters + f]).abs();
                }
                let (d, g) = (y_det[row * filters + f], y_fast[row * filters + f]);
                assert!(
                    (d - g).abs() <= reassoc_tol(kdim, s),
                    "{ctx} fwd [{row},{f}]: det {d} fast {g}"
                );
            }
        }

        // Input grad: each pixel sums `coverage × filters` terms.
        let mut dx_det = vec![0.0f32; h * w * c];
        conv2d_input_grad(&dy, &wmat, &mut dx_det, h, w, c, kernel, stride, filters);
        let mut dx_fast = vec![0.0f32; h * w * c];
        conv2d_input_grad_fast(&dy, &wmat, &mut dx_fast, h, w, c, kernel, stride, filters);
        let mut abs_sum = vec![0.0f32; h * w * c];
        let mut terms = vec![0usize; h * w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = oy * ow + ox;
                for ky in 0..kernel {
                    for i in 0..kernel * c {
                        let dst = ((oy * stride + ky) * w + ox * stride) * c + i;
                        let kk = ky * kernel * c + i;
                        for f in 0..filters {
                            abs_sum[dst] +=
                                (dy[row * filters + f] * wmat[kk * filters + f]).abs();
                        }
                        terms[dst] += filters;
                    }
                }
            }
        }
        for p in 0..h * w * c {
            let (d, g) = (dx_det[p], dx_fast[p]);
            assert!(
                (d - g).abs() <= reassoc_tol(terms[p], abs_sum[p]),
                "{ctx} igrad [{p}]: det {d} fast {g}"
            );
        }

        // Weight grad: reduction length nrow per gradient element.
        let mut dw_det = vec![0.0f32; kdim * filters];
        conv2d_weight_grad_chunk(&x, &dy, &mut dw_det, 0, kdim, h, w, c, kernel, stride, filters);
        let mut dw_fast = vec![0.0f32; kdim * filters];
        conv2d_weight_grad_chunk_fast(
            &x, &dy, &mut dw_fast, 0, kdim, h, w, c, kernel, stride, filters,
        );
        for kk in 0..kdim {
            for f in 0..filters {
                let mut s = 0.0f32;
                for row in 0..nrow {
                    s += (patches[row * kdim + kk] * dy[row * filters + f]).abs();
                }
                let (d, g) = (dw_det[kk * filters + f], dw_fast[kk * filters + f]);
                assert!(
                    (d - g).abs() <= reassoc_tol(nrow, s),
                    "{ctx} wgrad [{kk},{f}]: det {d} fast {g}"
                );
            }
        }
    }
}

/// The three direct kernels form a consistent adjoint triple: with
/// `y = x ⊛ W`, `dx = conv2d_input_grad(dy)` and `dW =
/// conv2d_weight_grad(x, dy)`, exact arithmetic gives
/// `⟨dy, y⟩ = ⟨x, dx⟩ = ⟨W, dW⟩`. Checked in f64 with an f32-rounding
/// tolerance, for both tiers.
#[test]
fn prop_direct_conv_adjoint_identities() {
    for case in 0..CASES {
        let mut rng = Rng::new(base_seed() ^ (0xAD01_17 + case));
        let (h, w, c, kernel, stride, filters) = conv_shape_wide(&mut rng);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let (nrow, kdim) = (oh * ow, kernel * kernel * c);
        let ctx = format!("case {case} (h={h} w={w} c={c} k={kernel} s={stride} f={filters})");
        let x = sparse_vec(&mut rng, h * w * c);
        let wmat = sparse_vec(&mut rng, kdim * filters);
        let dy = sparse_vec(&mut rng, nrow * filters);

        for fast in [false, true] {
            let mut y = vec![0.0f32; nrow * filters];
            let mut dx = vec![0.0f32; h * w * c];
            let mut dw = vec![0.0f32; kdim * filters];
            if fast {
                conv2d_forward_fast(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
                conv2d_input_grad_fast(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
                conv2d_weight_grad_chunk_fast(
                    &x, &dy, &mut dw, 0, kdim, h, w, c, kernel, stride, filters,
                );
            } else {
                conv2d_forward(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
                conv2d_input_grad(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
                conv2d_weight_grad_chunk(
                    &x, &dy, &mut dw, 0, kdim, h, w, c, kernel, stride, filters,
                );
            }
            let dot = |a: &[f32], b: &[f32]| -> f64 {
                a.iter().zip(b).map(|(&p, &q)| p as f64 * q as f64).sum()
            };
            let dyy = dot(&dy, &y);
            let xdx = dot(&x, &dx);
            let wdw = dot(&wmat, &dw);
            let scale = dy
                .iter()
                .zip(&y)
                .map(|(&p, &q)| (p as f64 * q as f64).abs())
                .sum::<f64>()
                .max(1e-9);
            for (name, v) in [("⟨x,dx⟩", xdx), ("⟨W,dW⟩", wdw)] {
                assert!(
                    (dyy - v).abs() / scale < 1e-4,
                    "{ctx} fast={fast}: ⟨dy,y⟩ = {dyy} vs {name} = {v}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// JSON substrate
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.f64() * 2e6).round() / 8.0 - 1e5),
        3 => {
            let n = rng.below_usize(8);
            Json::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below_usize(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below_usize(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES * 4 {
        let mut rng = Rng::new(seed);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}: {text}");
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let alphabet: Vec<char> = "{}[]\",:truefalsnl0123456789.eE+- ".chars().collect();
    for seed in 0..CASES * 8 {
        let mut rng = Rng::new(seed ^ 0x77);
        let len = rng.below_usize(40);
        let garbage: String = (0..len).map(|_| alphabet[rng.below_usize(alphabet.len())]).collect();
        let _ = Json::parse(&garbage); // must not panic
    }
}

// ---------------------------------------------------------------------------
// RNG + policy schedule
// ---------------------------------------------------------------------------

#[test]
fn prop_rng_below_always_in_range() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        for _ in 0..1_000 {
            let n = 1 + rng.below(1000);
            let x = rng.below(n);
            assert!(x < n, "seed {seed}: {x} >= {n}");
        }
    }
}

#[test]
fn prop_eps_schedule_monotone_and_bounded() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let start = rng.f64();
        let end = rng.f64() * start; // end <= start
        let decay = 1 + rng.below(1_000_000) as u64;
        let sched = EpsSchedule { start, end, decay_steps: decay };
        let mut prev = f64::INFINITY;
        for i in 0..50u64 {
            let step = i * decay / 40; // crosses past decay_steps
            let e = sched.at(step);
            assert!(e <= prev + 1e-12, "seed {seed}: schedule must be non-increasing");
            assert!(e <= start + 1e-12 && e >= end - 1e-12, "seed {seed}: out of bounds");
            prev = e;
        }
        assert_eq!(sched.at(decay), end);
        assert_eq!(sched.at(u64::MAX), end);
    }
}

// ---------------------------------------------------------------------------
// hwsim schedule bounds
// ---------------------------------------------------------------------------

fn random_model(rng: &mut Rng) -> CostModel {
    CostModel {
        env_step_ms: 0.1 + rng.f64(),
        serial_ms: rng.f64() * 0.5,
        txn_ms: 0.05 + rng.f64() * 0.5,
        infer_per_sample_ms: 0.01 + rng.f64() * 0.2,
        train_ms: 0.2 + rng.f64() * 2.0,
        train_parallel_frac: rng.f64(),
        sample_ms: rng.f64() * 0.3,
        tree_ms: rng.f64() * 0.2,
        sync_ms: rng.f64(),
        net_ms: rng.f64() * 0.5,
        cores: 1 + rng.below_usize(8),
        contention: rng.f64() * 0.5,
        batch_host_discount: 0.5 + rng.f64() * 0.5,
    }
}

#[test]
fn prop_hwsim_makespan_respects_lower_bound() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let model = random_model(&mut rng);
        let threads = 1 + rng.below_usize(8);
        let run = SimRun {
            steps: 2_000,
            c: 500,
            f: 4,
            threads,
            learner_threads: 1 + rng.below_usize(4),
            prefetch: rng.chance(0.5),
            prioritized: rng.chance(0.5),
            fleet_procs: rng.below_usize(4),
        };
        for mode in ExecMode::ALL {
            let stats = simulate(model, run, mode);
            // Synchronized modes run whole W-rounds, possibly overshooting.
            assert!(
                stats.env_steps >= run.steps && stats.env_steps < run.steps + threads as u64,
                "{mode:?} seed {seed}: env_steps {}",
                stats.env_steps
            );
            // Lower bound 1: total env CPU work / lanes.
            let env_lb = run.steps as f64 * model.env_step_ms / model.cores as f64;
            // Lower bound 2: device compute for the mandatory inferences.
            let gpu_lb = run.steps as f64 * model.infer_per_sample_ms;
            let lb = env_lb.max(gpu_lb);
            assert!(
                stats.makespan_ms >= lb * 0.999,
                "{mode:?} seed {seed}: makespan {} < lower bound {}",
                stats.makespan_ms,
                lb
            );
            assert!(stats.trains > 0, "{mode:?} seed {seed}: no training simulated");
        }
    }
}

#[test]
fn prop_hwsim_w1_standard_equals_closed_form() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5151);
        let mut model = random_model(&mut rng);
        model.cores = 1;
        model.contention = 0.0;
        let run = SimRun { steps: 1_000, c: 250, f: 4, threads: 1, ..SimRun::default() };
        let stats = simulate(model, run, ExecMode::Standard);
        // W=1 standard is fully serial: steps*(infer+serial+env) + trains
        // (each train pays txn + serial-learner compute + inline assembly).
        let expect = run.steps as f64
            * (model.infer_ms(1, 1) + model.serial_ms + model.env_step_ms)
            + (run.steps / run.f) as f64 * (model.train_total_ms(1) + model.sample_ms);
        let rel = (stats.makespan_ms - expect).abs() / expect;
        assert!(rel < 1e-6, "seed {seed}: {} vs {}", stats.makespan_ms, expect);
    }
}

// ---------------------------------------------------------------------------
// Gantt renderer robustness
// ---------------------------------------------------------------------------

#[test]
fn prop_gantt_render_never_panics() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xF00F);
        let g = GanttTrace::new(200);
        let spans = rng.below_usize(50);
        for _ in 0..spans {
            let lane = rng.below_usize(6);
            let phase = Phase::ALL[rng.below_usize(Phase::COUNT)];
            let a = rng.next_u64() % 1_000_000;
            let b = a + rng.next_u64() % 10_000;
            g.record(lane, phase, a, b);
        }
        let cols = 1 + rng.below_usize(120);
        let out = g.render_ascii(cols);
        assert!(!out.is_empty());
    }
}
