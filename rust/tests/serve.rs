//! End-to-end tests for the policy-serving daemon (ISSUE 9,
//! rust/DESIGN.md §15), over real unix sockets with handcrafted
//! checkpoints (no training needed — `CheckpointWriter` + `QNetSnapshot`
//! build a servable `step_<N>/` directly):
//!
//! * the acceptance bar: N concurrent clients' batched replies are
//!   **bitwise identical** to direct single-sample `QNet::infer` under the
//!   same theta, actions matching `argmax` of the rows;
//! * hot-swap under load: every reply's Q-row matches the checkpoint step
//!   it reports — the swap lock never lets a reply pair one checkpoint's
//!   theta with another's step, and no in-flight request is dropped;
//! * a corrupt newer checkpoint is skipped with a `swap_skips` tick while
//!   the old theta keeps serving, and a later valid checkpoint recovers;
//! * a client sending garbage bytes loses its connection, not the daemon.
#![cfg(unix)]

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tempo_dqn::agent::argmax;
use tempo_dqn::ckpt::CheckpointWriter;
use tempo_dqn::env::STATE_BYTES;
use tempo_dqn::net::{Conn, Endpoint};
use tempo_dqn::runtime::{default_artifact_dir, Device, Head, Manifest, Policy, QNet, QNetSnapshot};
use tempo_dqn::serve::{ServeClient, ServeOpts, Server};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tempo-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn sock_addr(tag: &str) -> String {
    let p = std::env::temp_dir().join(format!("tempo-serve-{tag}-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&p);
    format!("unix:{}", p.display())
}

/// A tiny-net QNet with deterministic parameters. `scale`/`shift` derive
/// distinct thetas from the builtin init so different checkpoints are
/// distinguishable to the bit.
fn make_qnet(scale: f32, shift: f32) -> QNet {
    make_qnet_head(Head::Dqn, scale, shift)
}

/// Same, for an explicit head — `+dueling` / `+c51[...]` checkpoints.
fn make_qnet_head(head: Head, scale: f32, shift: f32) -> QNet {
    let device = Arc::new(Device::cpu().unwrap());
    let manifest = Manifest::load_or_builtin(&default_artifact_dir()).unwrap();
    let qnet = QNet::load_with_head(device, &manifest, "tiny", false, 32, head).unwrap();
    if scale != 1.0 || shift != 0.0 {
        let theta: Vec<f32> =
            qnet.theta_host().unwrap().iter().map(|v| v * scale + shift).collect();
        qnet.set_theta(&theta).unwrap();
    }
    qnet
}

fn write_ckpt(dir: &Path, step: u64, qnet: &QNet) -> PathBuf {
    let mut w = CheckpointWriter::new(step);
    w.add(&QNetSnapshot(qnet)).unwrap();
    w.write(dir).unwrap()
}

/// Deterministic pseudo-random stacked frames (LCG high bytes).
fn states(n: usize, salt: u64) -> Vec<u8> {
    let mut rng: u64 = 0x9E37_79B9_7F4A_7C15 ^ salt.wrapping_mul(0x2545_F491_4F6C_DD1D);
    let mut out = vec![0u8; n * STATE_BYTES];
    for px in out.iter_mut() {
        rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *px = (rng >> 56) as u8;
    }
    out
}

fn assert_rows_match(qnet: &QNet, s: &[u8], n: usize, q: &[f32], actions: &[u8], ctx: &str) {
    let per = qnet.spec().actions;
    assert_eq!(q.len(), n * per, "{ctx}: row count");
    assert_eq!(actions.len(), n, "{ctx}: action count");
    for j in 0..n {
        let want = qnet
            .infer(Policy::Theta, &s[j * STATE_BYTES..(j + 1) * STATE_BYTES], 1)
            .unwrap();
        let got = &q[j * per..(j + 1) * per];
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "{ctx}: row {j} not bit-identical");
        assert_eq!(actions[j] as usize, argmax(&want), "{ctx}: action {j}");
    }
}

fn poll_until(handle: &tempo_dqn::serve::ServerHandle, what: &str, f: impl Fn(&tempo_dqn::net::ServeStats) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        if f(&handle.stats()) {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn concurrent_clients_get_rows_bitwise_equal_to_direct_infer() {
    let dir = tmpdir("e2e");
    let qnet = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 100, &qnet);

    let opts = ServeOpts {
        max_batch: 16,
        flush: Duration::from_millis(2),
        poll: Duration::from_millis(500),
    };
    let handle = Server::start(&dir, &default_artifact_dir(), &sock_addr("e2e"), opts).unwrap();
    let addr = handle.addr().to_string();

    let mut clients = Vec::new();
    for c in 0..4u64 {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let mut client = ServeClient::connect(&addr, Duration::from_secs(20)).unwrap();
            let mut out = Vec::new();
            for i in 0..8u64 {
                // Mixed request widths exercise the row-splitting paths.
                let n = 1 + (i as usize % 3);
                let s = states(n, c * 1_000 + i);
                let reply = client.act(&s, n).unwrap();
                assert_eq!(reply.step, 100);
                out.push((s, n, reply));
            }
            out
        }));
    }
    for t in clients {
        for (s, n, reply) in t.join().unwrap() {
            assert_rows_match(&qnet, &s, n, &reply.q, &reply.actions, "e2e");
        }
    }

    let stats = handle.stats();
    assert_eq!(stats.step, 100);
    assert_eq!(stats.requests, 4 * 8);
    assert_eq!(stats.states, 4 * (1 + 2 + 3 + 1 + 2 + 3 + 1 + 2));
    assert_eq!(stats.swaps, 0);
    let flushes: u64 = stats.batch_hist.iter().map(|&(_, c)| c).sum();
    assert!(flushes >= 1, "batch histogram recorded no flushes");
    let hist_states: u64 = stats.batch_hist.iter().map(|&(w, c)| w * c).sum();
    assert_eq!(hist_states, stats.states, "histogram accounts for every state");
    assert!(stats.lat_us[3] >= stats.lat_us[0], "max latency below p50");

    handle.stop().unwrap();
}

#[test]
fn stats_over_the_wire_match_local_snapshot_shape() {
    let dir = tmpdir("stats");
    let qnet = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 7, &qnet);
    let handle = Server::start(
        &dir,
        &default_artifact_dir(),
        &sock_addr("stats"),
        ServeOpts::default(),
    )
    .unwrap();

    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let s = states(1, 9);
    client.act(&s, 1).unwrap();
    let wire = client.stats().unwrap();
    assert_eq!(wire.step, 7);
    assert_eq!(wire.requests, 1);
    assert_eq!(wire.states, 1);
    assert_eq!(wire.batch_hist, vec![(1, 1)]);
    assert!(wire.lat_us[0] > 0, "p50 latency recorded");

    // Shutdown over the wire stops the whole daemon (the CLI's exit path).
    client.shutdown("test done").unwrap();
    handle.wait().unwrap();
}

#[test]
fn hot_swap_under_load_keeps_theta_and_step_paired() {
    let dir = tmpdir("swap");
    let qnet_a = make_qnet(1.0, 0.0);
    let qnet_b = make_qnet(0.5, 0.01);
    write_ckpt(&dir, 100, &qnet_a);

    let opts = ServeOpts {
        max_batch: 8,
        flush: Duration::from_micros(200),
        poll: Duration::from_millis(20),
    };
    let handle = Server::start(&dir, &default_artifact_dir(), &sock_addr("swap"), opts).unwrap();
    let addr = handle.addr().to_string();

    // Load thread: hammer the daemon across the swap; verify afterwards.
    let stop = Arc::new(AtomicBool::new(false));
    let loader = {
        let addr = addr.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut client = ServeClient::connect(&addr, Duration::from_secs(20)).unwrap();
            let mut replies = Vec::new();
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let s = states(1, 50_000 + i);
                let reply = client.act(&s, 1).unwrap();
                replies.push((s, reply));
                i += 1;
            }
            replies
        })
    };

    // Let some requests land under step 100, then publish step 200.
    std::thread::sleep(Duration::from_millis(50));
    write_ckpt(&dir, 200, &qnet_b);
    poll_until(&handle, "hot-swap to step 200", |s| s.step == 200);
    // A few more requests under the new theta before stopping the load.
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::Relaxed);
    let replies = loader.join().unwrap();

    assert!(!replies.is_empty());
    let mut seen_old = false;
    let mut seen_new = false;
    for (s, reply) in &replies {
        // The pairing invariant: whatever step a reply reports, its row
        // matches that checkpoint's theta exactly.
        let reference = match reply.step {
            100 => {
                seen_old = true;
                &qnet_a
            }
            200 => {
                seen_new = true;
                &qnet_b
            }
            other => panic!("reply reports unknown step {other}"),
        };
        assert_rows_match(reference, s, 1, &reply.q, &reply.actions, "swap");
    }
    assert!(seen_old, "no replies served under the original checkpoint");

    let stats = handle.stats();
    assert_eq!(stats.swaps, 1);
    assert_eq!(stats.swap_skips, 0);

    // Post-swap requests must serve the new theta.
    let mut client = ServeClient::connect(&addr, Duration::from_secs(20)).unwrap();
    let s = states(2, 777);
    let reply = client.act(&s, 2).unwrap();
    assert_eq!(reply.step, 200);
    assert!(seen_new || reply.step == 200);
    assert_rows_match(&qnet_b, &s, 2, &reply.q, &reply.actions, "post-swap");

    handle.stop().unwrap();
}

#[test]
fn corrupt_checkpoint_is_skipped_then_a_valid_one_recovers() {
    let dir = tmpdir("corrupt");
    let side = tmpdir("corrupt-side");
    let qnet_a = make_qnet(1.0, 0.0);
    let qnet_b = make_qnet(2.0, -0.02);
    write_ckpt(&dir, 100, &qnet_a);

    let opts = ServeOpts {
        max_batch: 8,
        flush: Duration::from_micros(200),
        poll: Duration::from_millis(20),
    };
    let handle =
        Server::start(&dir, &default_artifact_dir(), &sock_addr("corrupt"), opts).unwrap();

    // Build step 300 in a side directory, corrupt its section payload,
    // then move it into the watched dir — the watcher must never observe
    // the pre-corruption bytes.
    let staged = write_ckpt(&side, 300, &qnet_b);
    let state_bin = staged.join("state.bin");
    let mut bytes = std::fs::read(&state_bin).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&state_bin, &bytes).unwrap();
    std::fs::rename(&staged, dir.join(staged.file_name().unwrap())).unwrap();

    poll_until(&handle, "corrupt checkpoint skip", |s| s.swap_skips >= 1);
    let stats = handle.stats();
    assert_eq!(stats.step, 100, "daemon must keep serving the old step");
    assert_eq!(stats.swaps, 0);

    // Old theta still serves correctly.
    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let s = states(1, 42);
    let reply = client.act(&s, 1).unwrap();
    assert_eq!(reply.step, 100);
    assert_rows_match(&qnet_a, &s, 1, &reply.q, &reply.actions, "after-skip");

    // A valid, newer checkpoint supersedes the corrupt one.
    write_ckpt(&dir, 400, &qnet_b);
    poll_until(&handle, "recovery swap to step 400", |s| s.step == 400);
    let reply = client.act(&s, 1).unwrap();
    assert_eq!(reply.step, 400);
    assert_rows_match(&qnet_b, &s, 1, &reply.q, &reply.actions, "recovered");

    let stats = handle.stats();
    assert!(stats.swap_skips >= 1);
    assert_eq!(stats.swaps, 1);

    handle.stop().unwrap();
}

/// One request wider than the largest loaded engine batch (256 for the
/// builtin manifest): `QNet::infer` must chunk it across multiple engine
/// transactions, and every row of the daemon's reply must still be
/// bitwise-identical to single-sample inference. Pre-PR this request
/// died inside the collector with a "no infer batch >= 260" error.
#[test]
fn oversize_request_is_chunked_and_stays_bitwise_exact() {
    let dir = tmpdir("oversize");
    let qnet = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 31, &qnet);

    let opts = ServeOpts {
        max_batch: 16, // far below the request width: the request rides alone
        flush: Duration::from_micros(200),
        poll: Duration::from_millis(500),
    };
    let handle =
        Server::start(&dir, &default_artifact_dir(), &sock_addr("oversize"), opts).unwrap();

    let n = 260; // > 256, the largest builtin infer entry
    let s = states(n, 606);
    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let reply = client.act(&s, n).unwrap();
    assert_eq!(reply.step, 31);

    // Bitwise against one direct oversize infer (the same chunked path)…
    let direct = qnet.infer(Policy::Theta, &s, n).unwrap();
    let got: Vec<u32> = reply.q.iter().map(|v| v.to_bits()).collect();
    let want: Vec<u32> = direct.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "daemon rows diverge from direct chunked infer");
    // …and per-row against single-sample inference (the ground truth).
    assert_rows_match(&qnet, &s, n, &reply.q, &reply.actions, "oversize");

    handle.stop().unwrap();
}

/// The collector's idle wait is untimed and relies on `stop()` notifying
/// the condvar. If that contract ever breaks, an idle daemon's stop()
/// hangs on the collector join forever — so a bounded stop IS the test.
#[test]
fn idle_daemon_stops_promptly() {
    let dir = tmpdir("idle-stop");
    let qnet = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 1, &qnet);
    let handle = Server::start(
        &dir,
        &default_artifact_dir(),
        &sock_addr("idle-stop"),
        ServeOpts::default(),
    )
    .unwrap();
    // No requests queued: the collector is parked in its idle wait.
    let t0 = Instant::now();
    handle.stop().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "idle shutdown took {:?} — collector stop notification lost?",
        t0.elapsed()
    );
}

/// A corrupt checkpoint that is repaired *in place* — same `step_<N>`
/// directory, no newer step ever arriving — must be probed again and
/// swapped in. Pre-PR the warn-once guard keyed on the path alone, so
/// the repaired checkpoint was ignored forever.
#[test]
fn repaired_in_place_checkpoint_is_reprobed_and_swapped() {
    let dir = tmpdir("repair");
    let side = tmpdir("repair-side");
    let qnet_a = make_qnet(1.0, 0.0);
    let qnet_b = make_qnet(1.5, 0.005);
    write_ckpt(&dir, 100, &qnet_a);

    let opts = ServeOpts {
        max_batch: 8,
        flush: Duration::from_micros(200),
        poll: Duration::from_millis(20),
    };
    let handle =
        Server::start(&dir, &default_artifact_dir(), &sock_addr("repair"), opts).unwrap();

    // Stage step 300, corrupt its payload, move it in.
    let staged = write_ckpt(&side, 300, &qnet_b);
    let state_bin = staged.join("state.bin");
    let good_bytes = std::fs::read(&state_bin).unwrap();
    let mut bad_bytes = good_bytes.clone();
    let mid = bad_bytes.len() / 2;
    bad_bytes[mid] ^= 0x40;
    std::fs::write(&state_bin, &bad_bytes).unwrap();
    let landed = dir.join(staged.file_name().unwrap());
    std::fs::rename(&staged, &landed).unwrap();

    poll_until(&handle, "corrupt checkpoint skip", |s| s.swap_skips >= 1);
    assert_eq!(handle.stats().step, 100);

    // Repair in place: restore the original bytes under the same path.
    // Write-then-rename so the watcher can never observe a torn repair.
    let tmp = landed.join("state.bin.tmp");
    std::fs::write(&tmp, &good_bytes).unwrap();
    std::fs::rename(&tmp, landed.join("state.bin")).unwrap();
    poll_until(&handle, "re-probe of repaired checkpoint", |s| s.step == 300);

    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let s = states(1, 303);
    let reply = client.act(&s, 1).unwrap();
    assert_eq!(reply.step, 300);
    assert_rows_match(&qnet_b, &s, 1, &reply.q, &reply.actions, "repaired");

    handle.stop().unwrap();
}

/// The daemon serves whatever head its checkpoint names (`+dueling` here),
/// and refuses a later checkpoint whose head does not match its own —
/// by name, with a `swap_skips` tick, while the old theta keeps serving.
#[test]
fn daemon_serves_non_dqn_heads_and_refuses_head_mismatched_swaps() {
    let dir = tmpdir("heads");
    let duel = make_qnet_head(Head::Dueling, 1.0, 0.0);
    write_ckpt(&dir, 100, &duel);

    let opts = ServeOpts {
        max_batch: 8,
        flush: Duration::from_micros(200),
        poll: Duration::from_millis(20),
    };
    let handle =
        Server::start(&dir, &default_artifact_dir(), &sock_addr("heads"), opts).unwrap();

    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let s = states(3, 77);
    let reply = client.act(&s, 3).unwrap();
    assert_eq!(reply.step, 100);
    assert_rows_match(&duel, &s, 3, &reply.q, &reply.actions, "dueling-serve");

    // A newer dqn-head checkpoint is a different network: skip by name.
    let dqn = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 200, &dqn);
    poll_until(&handle, "head-mismatch skip", |s| s.swap_skips >= 1);
    let stats = handle.stats();
    assert_eq!(stats.step, 100, "head-mismatched checkpoint must not swap in");
    assert_eq!(stats.swaps, 0);
    let reply = client.act(&s, 3).unwrap();
    assert_eq!(reply.step, 100);
    assert_rows_match(&duel, &s, 3, &reply.q, &reply.actions, "post-mismatch");

    handle.stop().unwrap();
}

#[test]
fn garbage_bytes_drop_that_connection_but_daemon_survives() {
    let dir = tmpdir("garbage");
    let qnet = make_qnet(1.0, 0.0);
    write_ckpt(&dir, 5, &qnet);
    let handle = Server::start(
        &dir,
        &default_artifact_dir(),
        &sock_addr("garbage"),
        ServeOpts::default(),
    )
    .unwrap();

    // Not a frame at all: wrong magic, then noise.
    let ep = Endpoint::parse(handle.addr()).unwrap();
    let mut raw = Conn::connect(&ep, Duration::from_secs(5)).unwrap();
    raw.write_all(b"XXXXgarbage-not-a-frame-at-all").unwrap();
    raw.flush().unwrap();

    // The daemon drops that connection and keeps serving everyone else.
    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let s = states(1, 11);
    let reply = client.act(&s, 1).unwrap();
    assert_eq!(reply.step, 5);
    assert_rows_match(&qnet, &s, 1, &reply.q, &reply.actions, "post-garbage");

    // A malformed act (wrong byte count for n) is refused by name and only
    // costs the offending client its connection.
    let mut bad = ServeClient::connect(handle.addr(), Duration::from_secs(20)).unwrap();
    let err = bad.act(&states(1, 12), 2).unwrap_err().to_string();
    assert!(err.contains("act refused"), "unexpected error: {err}");
    let reply = client.act(&s, 1).unwrap();
    assert_eq!(reply.step, 5);

    handle.stop().unwrap();
}
