//! Runtime validation on deterministic inputs.
//!
//! Two tiers:
//!
//! * **Golden-pinned** (`#[ignore]` by default): execute the compiled HLO
//!   artifacts and pin the numbers against `golden.json`, which
//!   `python/compile/golden.py` produced from the live JAX model. These
//!   prove the python -> HLO-text -> PJRT -> Rust pipeline is numerically
//!   faithful, but they require `make artifacts` plus the `xla`-featured
//!   build — neither exists in the offline environment, so they are marked
//!   ignored with that reason and run only where artifacts are available
//!   (`cargo test -- --ignored`).
//! * **Engine-agnostic**: invariants that must hold on ANY execution
//!   engine (theta/theta_minus lifecycle, batch padding, loss descent,
//!   bus accounting). These run everywhere, on the default native engine.

use std::sync::Arc;

use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, Policy, QNet, TrainBatch};
use tempo_dqn::util::json::Json;

/// Deterministic uint8 frames; mirrors `python/compile/golden.det_states`.
fn det_states(b: usize, h: usize, w: usize, c: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(b * h * w * c);
    for i in 0..b {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out.push(((i * 13 + y * 7 + x * 3 + ch * 11) % 256) as u8);
                }
            }
        }
    }
    out
}

fn load_golden() -> Json {
    let path = default_artifact_dir().join("golden.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}; run `make artifacts`", path.display()));
    Json::parse(&text).expect("golden.json parse")
}

fn setup(config: &str) -> (Arc<Device>, Manifest, QNet) {
    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir).expect("manifest");
    let device = Arc::new(Device::cpu().expect("device"));
    let qnet = QNet::load(device.clone(), &manifest, config, false, 32).expect("qnet");
    (device, manifest, qnet)
}

fn assert_close(got: &[f32], want: &[f64], tol: f64, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let diff = (*g as f64 - w).abs();
        let scale = w.abs().max(1.0);
        assert!(diff / scale < tol, "{ctx}[{i}]: got {g}, want {w} (rel {})", diff / scale);
    }
}

#[test]
#[ignore = "pins python-generated golden.json; requires `make artifacts` + an artifact-executing engine (--features xla), unavailable offline"]
fn tiny_infer_matches_golden() {
    let golden = load_golden();
    let (_device, _manifest, qnet) = setup("tiny");
    let [h, w, c] = qnet.spec().frame;
    for b in [1usize, 8] {
        let states = det_states(b, h, w, c);
        let q = qnet.infer(Policy::ThetaMinus, &states, b).expect("infer");
        let want: Vec<f64> = golden.at(&["tiny", &format!("infer_b{b}")]).unwrap()
            .as_arr().unwrap()
            .iter()
            .flat_map(|row| row.as_f64_vec().unwrap())
            .collect();
        assert_close(&q, &want, 1e-3, &format!("tiny infer_b{b}"));
    }
}

#[test]
#[ignore = "pins python-generated golden.json; requires `make artifacts` + an artifact-executing engine (--features xla), unavailable offline"]
fn small_infer_matches_golden() {
    let golden = load_golden();
    let (_device, _manifest, qnet) = setup("small");
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(8, h, w, c);
    let q = qnet.infer(Policy::ThetaMinus, &states, 8).expect("infer");
    let want: Vec<f64> = golden.at(&["small", "infer_b8"]).unwrap()
        .as_arr().unwrap()
        .iter()
        .flat_map(|row| row.as_f64_vec().unwrap())
        .collect();
    assert_close(&q, &want, 1e-3, "small infer_b8");
}

#[test]
fn theta_and_theta_minus_agree_at_init() {
    let (_device, _manifest, qnet) = setup("tiny");
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(4, h, w, c);
    let q1 = qnet.infer(Policy::Theta, &states, 4).unwrap();
    let q2 = qnet.infer(Policy::ThetaMinus, &states, 4).unwrap();
    assert_eq!(q1, q2);
}

#[test]
fn infer_pads_small_batches() {
    // Batch 3 has no compiled entry; runtime must pad to 4 and slice back.
    let (_device, _manifest, qnet) = setup("tiny");
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(3, h, w, c);
    let q3 = qnet.infer(Policy::ThetaMinus, &states, 3).unwrap();
    let a = qnet.spec().actions;
    assert_eq!(q3.len(), 3 * a);
    let q8 = qnet
        .infer(Policy::ThetaMinus, &det_states(8, h, w, c), 8)
        .unwrap();
    for i in 0..3 * a {
        assert!((q3[i] - q8[i]).abs() < 1e-4, "row {i}: {} vs {}", q3[i], q8[i]);
    }
}

fn golden_train_batch(qnet: &QNet) -> TrainBatch {
    let [h, w, c] = qnet.spec().frame;
    let b = 32usize;
    let actions = qnet.spec().actions;
    let states = det_states(b, h, w, c);
    // next_states: reverse of batch rows (mirrors golden.py's [::-1]).
    let frame = h * w * c;
    let mut next_states = Vec::with_capacity(b * frame);
    for i in (0..b).rev() {
        next_states.extend_from_slice(&states[i * frame..(i + 1) * frame]);
    }
    TrainBatch {
        states,
        next_states,
        actions: (0..b as i32).map(|i| i % actions as i32).collect(),
        rewards: (0..b as i64).map(|i| (i % 3 - 1) as f32).collect(),
        dones: (0..b).map(|i| if i % 7 == 0 { 1.0 } else { 0.0 }).collect(),
    }
}

#[test]
#[ignore = "pins python-generated golden.json; requires `make artifacts` + an artifact-executing engine (--features xla), unavailable offline"]
fn tiny_train_step_matches_golden() {
    let golden = load_golden();
    let (_device, _manifest, qnet) = setup("tiny");
    let batch = golden_train_batch(&qnet);
    let loss = qnet.train_step(&batch, 2.5e-4).expect("train");
    let want_loss = golden.at(&["tiny", "train_b32_loss"]).unwrap().as_f64().unwrap();
    assert!(
        (loss as f64 - want_loss).abs() < 1e-4,
        "loss: got {loss}, want {want_loss}"
    );

    let theta = qnet.theta_host().unwrap();
    let head: Vec<f64> = golden.at(&["tiny", "train_b32_param_head"]).unwrap().as_f64_vec().unwrap();
    assert_close(&theta[..8], &head, 1e-4, "param head");

    let sum: f64 = theta.iter().map(|&x| x as f64).sum();
    let want_sum = golden.at(&["tiny", "train_b32_param_sum"]).unwrap().as_f64().unwrap();
    assert!((sum - want_sum).abs() / want_sum.abs().max(1.0) < 1e-3,
            "param sum: got {sum}, want {want_sum}");
}

#[test]
fn train_updates_theta_but_not_theta_minus() {
    let (_device, _manifest, qnet) = setup("tiny");
    let before_tm = qnet.theta_minus_host().unwrap();
    let before_t = qnet.theta_host().unwrap();
    let batch = golden_train_batch(&qnet);
    qnet.train_step(&batch, 2.5e-4).unwrap();
    let after_t = qnet.theta_host().unwrap();
    let after_tm = qnet.theta_minus_host().unwrap();
    assert_ne!(before_t, after_t, "theta must change");
    assert_eq!(before_tm, after_tm, "theta_minus must be frozen until sync");

    qnet.sync_target();
    let synced = qnet.theta_minus_host().unwrap();
    assert_eq!(synced, after_t, "sync copies theta bit-exactly");
}

#[test]
fn repeated_train_steps_reduce_loss_on_fixed_batch() {
    let (_device, _manifest, qnet) = setup("tiny");
    let batch = golden_train_batch(&qnet);
    let first = qnet.train_step(&batch, 3e-3).unwrap();
    let mut last = first;
    for _ in 0..60 {
        last = qnet.train_step(&batch, 3e-3).unwrap();
    }
    assert!(
        last < first * 0.5,
        "loss should fall on a fixed batch: first {first}, last {last}"
    );
}

#[test]
fn bus_stats_count_transactions() {
    let (device, _manifest, qnet) = setup("tiny");
    device.stats.reset();
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(1, h, w, c);
    qnet.infer(Policy::ThetaMinus, &states, 1).unwrap();
    qnet.infer(Policy::ThetaMinus, &states, 1).unwrap();
    let snap = device.stats.snapshot();
    assert_eq!(snap.transactions, 2);
    assert!(snap.bytes_in > 0 && snap.bytes_out > 0 && snap.busy_ns > 0);
}
