//! Runtime validation on deterministic inputs.
//!
//! Two tiers:
//!
//! * **Golden-pinned**: the full runtime pipeline (`QNet` → `Device` →
//!   pooled/tiled `NativeEngine`) is pinned **bit-for-bit** against
//!   natively produced goldens from `runtime::golden` — the engine's
//!   original serial, whole-batch, naive-kernel math kept verbatim as an
//!   oracle. These run everywhere, at several learner-pool widths. They
//!   replace the retired python-generated `golden.json` pins, which
//!   required `make artifacts` plus the `--features xla` engine and were
//!   permanently `#[ignore]`d offline. NOTE the scope change: these pins
//!   catch any drift of the runtime pipeline from the preserved serial
//!   math, but NOT a shared divergence from `python/compile/model.py` —
//!   that cross-check was retired with the XLA path and would need the
//!   old golden.json tests restored from git history once the `xla`
//!   crate is vendored (rust/DESIGN.md §2).
//! * **Engine-agnostic**: invariants that must hold on ANY execution
//!   engine (theta/theta_minus lifecycle, batch padding, loss descent,
//!   bus accounting).

use std::sync::Arc;

use tempo_dqn::runtime::{
    default_artifact_dir, golden, Device, Manifest, NetArch, Policy, QNet, TrainBatch,
};

/// Deterministic uint8 frames; mirrors `python/compile/golden.det_states`.
fn det_states(b: usize, h: usize, w: usize, c: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(b * h * w * c);
    for i in 0..b {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out.push(((i * 13 + y * 7 + x * 3 + ch * 11) % 256) as u8);
                }
            }
        }
    }
    out
}

fn setup(config: &str) -> (Arc<Device>, Manifest, QNet) {
    setup_with_threads(config, 1)
}

fn setup_with_threads(config: &str, learner_threads: usize) -> (Arc<Device>, Manifest, QNet) {
    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir).expect("manifest");
    let device = Arc::new(Device::cpu_with_threads(learner_threads).expect("device"));
    let qnet = QNet::load(device.clone(), &manifest, config, false, 32).expect("qnet");
    (device, manifest, qnet)
}

/// Initial parameters as the manifest (and therefore the QNet) produces
/// them, plus the architecture to evaluate the golden reference on.
fn golden_setup(config: &str) -> (NetArch, Vec<f32>) {
    let manifest = Manifest::load_or_builtin(&default_artifact_dir()).expect("manifest");
    let spec = manifest.config(config).expect("spec").clone();
    let arch = NetArch::from_spec(&spec).expect("arch");
    let theta = manifest.init_params(&spec).expect("init");
    (arch, theta)
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}[{i}]: engine {g} != golden {w} (bitwise)"
        );
    }
}

#[test]
fn tiny_infer_matches_native_golden() {
    let (arch, theta) = golden_setup("tiny");
    // Engine path (tiled kernels, pooled shards) vs serial naive oracle,
    // at 1 and 4 learner threads — all three must agree to the bit.
    for learner_threads in [1usize, 4] {
        let (_device, _manifest, qnet) = setup_with_threads("tiny", learner_threads);
        let [h, w, c] = qnet.spec().frame;
        for b in [1usize, 8] {
            let states = det_states(b, h, w, c);
            let q = qnet.infer(Policy::ThetaMinus, &states, b).expect("infer");
            let want = golden::reference_infer(&arch, &theta, &states, b).expect("golden");
            assert_bits_eq(&q, &want, &format!("tiny infer_b{b} (pool {learner_threads})"));
        }
    }
}

#[test]
fn small_infer_matches_native_golden() {
    let (arch, theta) = golden_setup("small");
    let (_device, _manifest, qnet) = setup_with_threads("small", 2);
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(8, h, w, c);
    let q = qnet.infer(Policy::ThetaMinus, &states, 8).expect("infer");
    let want = golden::reference_infer(&arch, &theta, &states, 8).expect("golden");
    assert_bits_eq(&q, &want, "small infer_b8");
}

#[test]
fn theta_and_theta_minus_agree_at_init() {
    let (_device, _manifest, qnet) = setup("tiny");
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(4, h, w, c);
    let q1 = qnet.infer(Policy::Theta, &states, 4).unwrap();
    let q2 = qnet.infer(Policy::ThetaMinus, &states, 4).unwrap();
    assert_eq!(q1, q2);
}

#[test]
fn infer_pads_small_batches() {
    // Batch 3 has no compiled entry; runtime must pad to 4 and slice back.
    let (_device, _manifest, qnet) = setup("tiny");
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(3, h, w, c);
    let q3 = qnet.infer(Policy::ThetaMinus, &states, 3).unwrap();
    let a = qnet.spec().actions;
    assert_eq!(q3.len(), 3 * a);
    let q8 = qnet
        .infer(Policy::ThetaMinus, &det_states(8, h, w, c), 8)
        .unwrap();
    for i in 0..3 * a {
        assert!((q3[i] - q8[i]).abs() < 1e-4, "row {i}: {} vs {}", q3[i], q8[i]);
    }
}

fn golden_train_batch(qnet: &QNet) -> TrainBatch {
    let [h, w, c] = qnet.spec().frame;
    let b = 32usize;
    let actions = qnet.spec().actions;
    let states = det_states(b, h, w, c);
    // next_states: reverse of batch rows (mirrors golden.py's [::-1]).
    let frame = h * w * c;
    let mut next_states = Vec::with_capacity(b * frame);
    for i in (0..b).rev() {
        next_states.extend_from_slice(&states[i * frame..(i + 1) * frame]);
    }
    TrainBatch {
        states,
        next_states,
        actions: (0..b as i32).map(|i| i % actions as i32).collect(),
        rewards: (0..b as i64).map(|i| (i % 3 - 1) as f32).collect(),
        dones: (0..b).map(|i| if i % 7 == 0 { 1.0 } else { 0.0 }).collect(),
        ..TrainBatch::default()
    }
}

#[test]
fn tiny_train_step_matches_native_golden() {
    let (arch, theta0) = golden_setup("tiny");
    for learner_threads in [1usize, 4] {
        let (_device, _manifest, qnet) = setup_with_threads("tiny", learner_threads);
        let batch = golden_train_batch(&qnet);
        let gamma = qnet.spec().gamma as f32;
        let zeros = vec![0.0f32; arch.param_count()];
        let want = golden::reference_train_step(
            &arch,
            &theta0,
            &theta0, // theta_minus == theta at init
            &zeros,
            &zeros,
            &batch,
            gamma,
            false,
            2.5e-4,
        )
        .expect("golden train");

        let loss = qnet.train_step(&batch, 2.5e-4).expect("train");
        assert_eq!(
            loss.to_bits(),
            want.loss.to_bits(),
            "pool {learner_threads}: loss {loss} != golden {}",
            want.loss
        );
        let theta = qnet.theta_host().unwrap();
        assert_bits_eq(&theta, &want.theta, &format!("theta' (pool {learner_threads})"));
    }
}

#[test]
fn train_updates_theta_but_not_theta_minus() {
    let (_device, _manifest, qnet) = setup("tiny");
    let before_tm = qnet.theta_minus_host().unwrap();
    let before_t = qnet.theta_host().unwrap();
    let batch = golden_train_batch(&qnet);
    qnet.train_step(&batch, 2.5e-4).unwrap();
    let after_t = qnet.theta_host().unwrap();
    let after_tm = qnet.theta_minus_host().unwrap();
    assert_ne!(before_t, after_t, "theta must change");
    assert_eq!(before_tm, after_tm, "theta_minus must be frozen until sync");

    qnet.sync_target();
    let synced = qnet.theta_minus_host().unwrap();
    assert_eq!(synced, after_t, "sync copies theta bit-exactly");
}

#[test]
fn repeated_train_steps_reduce_loss_on_fixed_batch() {
    let (_device, _manifest, qnet) = setup("tiny");
    let batch = golden_train_batch(&qnet);
    let first = qnet.train_step(&batch, 3e-3).unwrap();
    let mut last = first;
    for _ in 0..60 {
        last = qnet.train_step(&batch, 3e-3).unwrap();
    }
    assert!(
        last < first * 0.5,
        "loss should fall on a fixed batch: first {first}, last {last}"
    );
}

#[test]
fn bus_stats_count_transactions() {
    let (device, _manifest, qnet) = setup("tiny");
    device.stats.reset();
    let [h, w, c] = qnet.spec().frame;
    let states = det_states(1, h, w, c);
    qnet.infer(Policy::ThetaMinus, &states, 1).unwrap();
    qnet.infer(Policy::ThetaMinus, &states, 1).unwrap();
    let snap = device.stats.snapshot();
    assert_eq!(snap.transactions, 2);
    assert!(snap.bytes_in > 0 && snap.bytes_out > 0 && snap.busy_ns > 0);
}
