//! Train-step throughput across learner-pool widths — the measured side of
//! the parallel-learner tentpole (rust/DESIGN.md §9).
//!
//! Sweeps `learner_threads` × `kernel_mode` over the native engine's
//! sharded train step (deterministic: identical bits at every width —
//! pinned by tests; fast: vectorized kernels under the bounded divergence
//! contract, rust/DESIGN.md §12 — this bench measures the wall-clock
//! side of both tiers), and times minibatch assembly (`sample` +
//! `assemble`), i.e. the cost the prefetch pipeline removes from the
//! trainer's critical path.
//!
//! Run: `cargo bench --bench train_throughput`
//! CI smoke: `cargo bench --bench train_throughput -- --test`
//! (tiny net, 1-2 threads, ~60 ms per measurement).

use std::sync::{Arc, RwLock};

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::NET_FRAME;
use tempo_dqn::replay::{BatchSource, DirectSource, ReplayMemory};
use tempo_dqn::runtime::{
    default_artifact_dir, Device, Head, KernelMode, Manifest, QNet, TrainBatch,
};
use tempo_dqn::util::rng::Rng;

fn synthetic_batch(qnet: &QNet, seed: u64) -> TrainBatch {
    let [h, w, c] = qnet.spec().frame;
    let b = 32usize;
    let mut rng = Rng::new(seed);
    let frame = h * w * c;
    TrainBatch {
        states: (0..b * frame).map(|_| rng.below(256) as u8).collect(),
        next_states: (0..b * frame).map(|_| rng.below(256) as u8).collect(),
        actions: (0..b).map(|_| rng.below(qnet.spec().actions as u32) as i32).collect(),
        rewards: (0..b).map(|_| rng.f32() - 0.5).collect(),
        dones: (0..b).map(|i| if i % 6 == 0 { 1.0 } else { 0.0 }).collect(),
        ..TrainBatch::default()
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        // Keep the CI job seconds-scale; correctness is covered by tests.
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let nets: &[&str] = if smoke { &["tiny"] } else { &["tiny", "small"] };
    let widths: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };

    let manifest = Manifest::load_or_builtin(&default_artifact_dir()).expect("manifest");
    let mut bench = Bench::new();

    for net in nets {
        for mode in KernelMode::ALL {
            let mut base_ns = 0.0f64;
            for &threads in widths {
                let device = Arc::new(Device::cpu_with_opts(threads, mode).expect("device"));
                let qnet = QNet::load(device, &manifest, net, false, 32).expect("qnet");
                let batch = synthetic_batch(&qnet, 7);
                let r = bench
                    .run(
                        &format!("train/{net}/b32/{}/learner_threads{threads}", mode.name()),
                        || qnet.train_step(&batch, 2.5e-4).expect("train"),
                    )
                    .clone();
                if threads == 1 {
                    base_ns = r.mean_ns;
                } else if base_ns > 0.0 {
                    println!("         -> {:.2}x vs 1 thread", base_ns / r.mean_ns);
                }
            }
        }
        let det1 = bench.get(&format!("train/{net}/b32/deterministic/learner_threads1"));
        let fast1 = bench.get(&format!("train/{net}/b32/fast/learner_threads1"));
        if let (Some(d), Some(f)) = (det1, fast1) {
            println!("         => fast vs deterministic at 1 thread: {:.2}x", d.mean_ns / f.mean_ns);
        }
    }

    // Head-variant cost: C51 vs the dqn baseline at matched width. The
    // distributional tail multiplies the output layer by `atoms` and adds
    // the per-action softmax + target projection, so this pair is the
    // measured price of `net.head = c51` (rust/DESIGN.md §16). Heads are
    // native-engine only, so the pair runs on the synthetic manifest.
    let builtin = Manifest::builtin();
    for mode in KernelMode::ALL {
        let mut pair = [0.0f64; 2];
        for (i, head) in [Head::Dqn, Head::C51 { atoms: 51, v_min: -10.0, v_max: 10.0 }]
            .into_iter()
            .enumerate()
        {
            let device = Arc::new(Device::cpu_with_opts(1, mode).expect("device"));
            let qnet =
                QNet::load_with_head(device, &builtin, "tiny", false, 32, head).expect("qnet");
            let batch = synthetic_batch(&qnet, 7);
            let r = bench
                .run(
                    &format!("train/tiny/b32/{}/head_{}", mode.name(), head.kind_name()),
                    || qnet.train_step(&batch, 2.5e-4).expect("train"),
                )
                .clone();
            pair[i] = r.mean_ns;
        }
        if pair[0] > 0.0 {
            println!("         => c51 vs dqn ({}): {:.2}x", mode.name(), pair[1] / pair[0]);
        }
    }

    // Minibatch assembly: the host-side cost that `prefetch_batches > 0`
    // overlaps with the train step above. Feeds CostModel::sample_ms.
    let replay = {
        let mut r = ReplayMemory::new(100_000, 8, NET_FRAME, 4, 1).expect("replay");
        let frame = vec![127u8; NET_FRAME];
        for i in 0..20_000u64 {
            r.push((i % 8) as usize, &frame, 1, 0.5, i % 97 == 0, i % 97 == 1 || i < 8);
        }
        RwLock::new(r)
    };
    let source = DirectSource::new(&replay, 1, 32);
    let mut batch = TrainBatch::default();
    bench.run("sample/assemble_b32", || {
        source.next_batch(&mut batch, &|| false).expect("sample")
    });

    println!("\ntrain rows feed CostModel::train_parallel_frac; the sample row feeds CostModel::sample_ms");
    bench.emit_json("train_throughput").expect("bench json");
}
