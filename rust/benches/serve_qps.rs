//! Serving-path QPS and latency (rust/DESIGN.md §15).
//!
//! Three layers, innermost first:
//!
//! 1. `serve/direct_infer_1` — one single-sample `QNet::infer`, the floor
//!    every served row pays regardless of transport.
//! 2. `serve/act_roundtrip_1` — one 1-state act over a loopback socket
//!    through the micro-batching collector (daemon in-process): the
//!    protocol + batching overhead on top of (1).
//! 3. `serve/act_roundtrip_b8` — one 8-state act, the batched-QPS shape:
//!    per-state cost should drop well below (2)'s as the engine
//!    transaction amortizes.
//!
//! Run: `cargo bench --bench serve_qps`
//! CI smoke: `cargo bench --bench serve_qps -- --test`

use std::sync::Arc;
use std::time::Duration;

use tempo_dqn::benchkit::Bench;
use tempo_dqn::ckpt::CheckpointWriter;
use tempo_dqn::env::STATE_BYTES;
use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, Policy, QNet, QNetSnapshot};
use tempo_dqn::serve::{ServeClient, ServeOpts, Server};

fn states(n: usize, salt: u64) -> Vec<u8> {
    let mut rng: u64 = 0x9E37_79B9_7F4A_7C15 ^ salt;
    let mut out = vec![0u8; n * STATE_BYTES];
    for px in out.iter_mut() {
        rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *px = (rng >> 56) as u8;
    }
    out
}

fn bind_addr() -> String {
    if cfg!(unix) {
        let dir = std::env::temp_dir().join(format!("tempo-serve-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("bench tmp dir");
        format!("unix:{}", dir.join("serve.sock").display())
    } else {
        "tcp:127.0.0.1:0".to_string()
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let mut bench = Bench::new();

    // A servable checkpoint, no training needed.
    let device = Arc::new(Device::cpu().expect("device"));
    let manifest = Manifest::load_or_builtin(&default_artifact_dir()).expect("manifest");
    let qnet = QNet::load(device, &manifest, "tiny", false, 32).expect("qnet");
    let ckpt_dir =
        std::env::temp_dir().join(format!("tempo-serve-bench-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    std::fs::create_dir_all(&ckpt_dir).expect("ckpt dir");
    let mut w = CheckpointWriter::new(1);
    w.add(&QNetSnapshot(&qnet)).expect("snapshot");
    w.write(&ckpt_dir).expect("checkpoint");

    // 1. The floor: direct single-sample inference, no transport.
    let s1 = states(1, 11);
    let r = bench.run("serve/direct_infer_1", || {
        qnet.infer(Policy::Theta, &s1, 1).unwrap().len()
    });
    println!("direct single-sample infer: {:9.1} us", r.mean_ns / 1e3);

    // In-process daemon on a loopback socket. Flush 0: a lone blocking
    // client gains nothing from waiting for co-riders, and the deadline
    // would otherwise dominate every round trip.
    let opts = ServeOpts {
        max_batch: 32,
        flush: Duration::ZERO,
        poll: Duration::from_millis(500),
    };
    let handle =
        Server::start(&ckpt_dir, &default_artifact_dir(), &bind_addr(), opts).expect("daemon");
    let mut client = ServeClient::connect(handle.addr(), Duration::from_secs(30)).expect("client");

    // 2. Protocol + collector overhead at width 1.
    let r = bench.run("serve/act_roundtrip_1", || client.act(&s1, 1).unwrap().step);
    println!(
        "served act (1 state) loopback roundtrip: {:9.1} us ({:8.0} QPS)",
        r.mean_ns / 1e3,
        r.throughput_per_sec()
    );

    // 3. Batched shape: 8 states per request.
    let s8 = states(8, 22);
    let r = bench.run("serve/act_roundtrip_b8", || client.act(&s8, 8).unwrap().step);
    println!(
        "served act (8 states) loopback roundtrip: {:9.1} us ({:8.0} states/s)",
        r.mean_ns / 1e3,
        r.throughput_per_sec() * 8.0
    );

    let stats = handle.stats();
    println!(
        "daemon stats: requests={} states={} flush-widths={:?} lat p50={}us p99={}us",
        stats.requests,
        stats.states,
        stats.batch_hist,
        stats.lat_us[0],
        stats.lat_us[2]
    );
    drop(client);
    handle.stop().expect("daemon stop");
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    bench.emit_json("serve").expect("bench json");
}
