//! Fleet wire-path throughput (rust/DESIGN.md §14).
//!
//! Three layers, innermost first:
//!
//! 1. `fleet/param_frame` — encode + frame + checksum + decode of one
//!    parameter broadcast (the per-barrier learner→sampler cost, paid
//!    once per connection per window).
//! 2. `fleet/upload_roundtrip` — one C-step window upload over a loopback
//!    TCP connection, acknowledged (the sampler→learner cost, the frame
//!    bytes dominating).
//! 3. `fleet/steps_1p` / `fleet/steps_2p` — end-to-end replicated fleet
//!    runs (learner in-process, real spawned `fleet-sampler` worker
//!    processes) in transitions/sec, the number `CostModel::net_ms`
//!    should be calibrated against (`hwsim/cost.rs`).
//!
//! Run: `cargo bench --bench fleet_throughput`
//! CI smoke: `cargo bench --bench fleet_throughput -- --test`

use std::io::Cursor;
use std::path::Path;
use std::time::{Duration, Instant};

use tempo_dqn::benchkit::Bench;
use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::{spawn_local_samplers, Coordinator, FleetOpts};
use tempo_dqn::env::NET_FRAME;
use tempo_dqn::net::{Endpoint, Msg, WindowUpload};
use tempo_dqn::replay::StagedTransition;
use tempo_dqn::runtime::default_artifact_dir;

fn fleet_cfg(total: u64, samplers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.game = "seeker".into();
    cfg.mode = ExecMode::Concurrent;
    cfg.threads = 2;
    cfg.envs_per_thread = 2;
    cfg.total_steps = total;
    cfg.target_update_period = 64;
    cfg.train_period = 4;
    cfg.prepopulate = 300;
    cfg.replay_capacity = 8_000;
    cfg.fleet_samplers = samplers;
    cfg
}

/// One full replicated fleet run; records transitions/sec under `name`.
fn fleet_steps(bench: &mut Bench, name: &str, samplers: usize, total: u64) -> f64 {
    let cfg = fleet_cfg(total, samplers);
    let sock = std::env::temp_dir()
        .join(format!("tempo-fleet-bench-{samplers}-{}.sock", std::process::id()));
    let bind = format!("unix:{}", sock.display());
    let bin = Path::new(env!("CARGO_BIN_EXE_tempo-dqn"));
    let mut children = spawn_local_samplers(bin, &cfg, &bind, samplers).expect("spawn samplers");
    let mut coord = Coordinator::new(cfg, &default_artifact_dir()).expect("learner");
    let t0 = Instant::now();
    let res = coord.run_fleet(&FleetOpts { bind, samplers }, None).expect("fleet run");
    let ns = t0.elapsed().as_nanos() as f64;
    for child in &mut children {
        child.wait().expect("sampler exit");
    }
    bench.record(name, res.steps, ns).throughput_per_sec()
}

fn synthetic_upload(steps: usize) -> WindowUpload {
    let per_stream = steps / 4;
    let streams = (0..4u64)
        .map(|s| {
            let items = (0..per_stream)
                .map(|i| StagedTransition {
                    frame: vec![(i % 251) as u8; NET_FRAME],
                    action: (i % 4) as u8,
                    reward: 0.25,
                    done: i % 37 == 36,
                    start: i % 37 == 0,
                })
                .collect();
            (s, items)
        })
        .collect();
    WindowUpload {
        window: 3,
        steps: steps as u64,
        episodes: 2,
        returns: vec![(100, 1.5), (160, 2.5)],
        ctxs: vec![vec![7u8; 4 * NET_FRAME]; 1],
        streams,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let mut bench = Bench::new();

    // 1. Parameter broadcast: frame + checksum + codec, round trip.
    let theta: Vec<f32> = (0..64_000).map(|i| (i as f32).sin() * 1e-2).collect();
    let r = bench.run("fleet/param_frame", || {
        let mut buf = Vec::with_capacity(theta.len() * 4 + 64);
        Msg::ParamBroadcast { tag: 7, theta_minus: theta.clone() }.send(&mut buf).unwrap();
        match Msg::recv(&mut Cursor::new(&buf)).unwrap() {
            Msg::ParamBroadcast { theta_minus, .. } => theta_minus.len(),
            _ => unreachable!(),
        }
    });
    println!(
        "param broadcast (64k f32, encode+checksum+decode): {:9.1} us",
        r.mean_ns / 1e3
    );

    // 2. One window upload (C = 64 steps of staged frames) over loopback
    // TCP, acknowledged by the peer.
    let listener = Endpoint::parse("tcp:127.0.0.1:0").unwrap().bind().unwrap();
    let addr = listener.local_addr_string().unwrap();
    let sink = std::thread::spawn(move || {
        let mut conn = listener.accept().unwrap();
        while let Ok(msg) = Msg::recv(&mut conn) {
            if matches!(msg, Msg::Shutdown { .. }) {
                break;
            }
            Msg::Heartbeat.send(&mut conn).unwrap();
        }
    });
    let mut conn = Endpoint::parse(&addr).unwrap().connect(Duration::from_secs(5)).unwrap();
    let r = bench.run("fleet/upload_roundtrip", || {
        Msg::Upload(synthetic_upload(64)).send(&mut conn).unwrap();
        matches!(Msg::recv(&mut conn).unwrap(), Msg::Heartbeat)
    });
    let frame_bytes = 64 * NET_FRAME;
    println!(
        "window upload (64 steps, ~{:.1} KB frames) loopback roundtrip: {:9.1} us  ({:.2} GB/s)",
        frame_bytes as f64 / 1e3,
        r.mean_ns / 1e3,
        frame_bytes as f64 / r.mean_ns.max(1.0)
    );
    Msg::Shutdown { reason: "bench done".into() }.send(&mut conn).unwrap();
    sink.join().unwrap();

    // 3. End-to-end replicated fleet runs against real worker processes.
    let total: u64 = if smoke { 384 } else { 3_840 };
    let one = fleet_steps(&mut bench, "fleet/steps_1p", 1, total);
    let two = fleet_steps(&mut bench, "fleet/steps_2p", 2, total);
    println!("fleet end-to-end ({total} steps, replicated): 1 proc {one:8.0} steps/s");
    println!("fleet end-to-end ({total} steps, replicated): 2 proc {two:8.0} steps/s");
    println!("\n(calibrate hwsim CostModel.net_ms from the barrier-level costs above)");
    bench.emit_json("fleet").expect("bench json");
}
