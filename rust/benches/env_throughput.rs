//! Environment bench: per-game agent-step cost (simulate 4 raw ticks +
//! render + max-pool + downscale + stack) — the CPU side of the paper's
//! hardware model, and the denominator of its speedup argument.
//!
//! Run: `cargo bench --bench env_throughput`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::{make_env, GAMES, STATE_BYTES};

fn main() {
    let mut bench = Bench::new();
    for game in GAMES {
        let mut env = make_env(game, 3).unwrap();
        let mut i = 0usize;
        bench.run(&format!("env/{game}/step"), || {
            let r = env.step(i % env.num_actions());
            i += 1;
            if r.done {
                env.reset();
            }
        });
    }
    // State assembly (interleaving 4 planes channel-last).
    let env = make_env("pong", 3).unwrap();
    let mut out = vec![0u8; STATE_BYTES];
    bench.run("env/write_state", || env.write_state(&mut out));

    println!("\nper-step env cost feeds hwsim::CostModel::from_measured");
}
