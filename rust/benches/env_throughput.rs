//! Environment bench: per-game agent-step cost (simulate 4 raw ticks +
//! render + max-pool + downscale + stack) — the CPU side of the paper's
//! hardware model, and the denominator of its speedup argument — plus a
//! B-sweep over `VecEnv` widths measuring the per-step cost of batched
//! stream stepping and contiguous state assembly (the W×B axis).
//!
//! Run: `cargo bench --bench env_throughput`
//! CI smoke: `cargo bench --bench env_throughput -- --test`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::{make_env, VecEnv, GAMES, STATE_BYTES};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let b_sweep: &[usize] = if smoke { &[1, 8] } else { &[1, 2, 4, 8, 16] };

    let mut bench = Bench::new();
    for game in GAMES {
        let mut env = make_env(game, 3).unwrap();
        let mut i = 0usize;
        bench.run(&format!("env/{game}/step"), || {
            let r = env.step(i % env.num_actions());
            i += 1;
            if r.done {
                env.reset();
            }
        });
    }
    // State assembly (interleaving 4 planes channel-last).
    let env = make_env("pong", 3).unwrap();
    let mut out = vec![0u8; STATE_BYTES];
    bench.run("env/write_state", || env.write_state(&mut out));

    // B-sweep: stepping B streams per iteration + assembling the
    // contiguous B-state inference input. Per-env-step cost should stay
    // flat while the per-transaction batch grows B-fold.
    println!();
    for &b in b_sweep {
        let seeds: Vec<u64> = (0..b as u64).map(|j| 3 + j * 7919).collect();
        let mut vec_env = VecEnv::new("pong", &seeds).unwrap();
        let actions = vec_env.num_actions();
        let mut acts = vec![0usize; b];
        let mut results = Vec::with_capacity(b);
        let mut i = 0usize;
        let r = bench.run(&format!("vecenv/pong/step_batch/b{b}"), || {
            for (j, a) in acts.iter_mut().enumerate() {
                *a = (i + j) % actions;
            }
            i += 1;
            vec_env.step_batch(&acts, &mut results);
            for (j, r) in results.iter().enumerate() {
                if r.done {
                    vec_env.reset(j);
                }
            }
        });
        println!(
            "         -> {:.3} us/env-step at B={b}",
            r.mean_ns / 1e3 / b as f64
        );

        let mut states = vec![0u8; b * STATE_BYTES];
        bench.run(&format!("vecenv/pong/write_states/b{b}"), || {
            vec_env.write_states(&mut states)
        });
    }

    println!("\nper-step env cost feeds hwsim::CostModel::from_measured");
    bench.emit_json("env_throughput").expect("bench json");
}
