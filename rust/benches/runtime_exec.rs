//! Device-runtime bench: inference at several batch sizes, the batch-32
//! train step, target sync, and the per-layer conv-kernel pairs
//! (im2col+matmul vs the patch-free direct kernels, rust/DESIGN.md §13) —
//! the accelerator side of the hardware model. The b1-vs-b8 gap measures
//! the per-transaction overhead that Synchronized Execution amortizes
//! (paper §4); the `conv*/..._im2col` vs `conv*/..._direct` gaps measure
//! the patch-materialization traffic the direct kernels eliminate.
//!
//! Run: `cargo bench --bench runtime_exec`
//! CI smoke: `cargo bench --bench runtime_exec -- --test`

use std::sync::Arc;

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::{make_env, STATE_BYTES};
use tempo_dqn::runtime::kernels::{
    col2im_sample, conv2d_forward, conv2d_input_grad, conv2d_weight_grad_chunk, im2col_sample,
    matmul_a_bt_tiled, matmul_acc_tiled, matmul_at_b_acc_tiled,
};
use tempo_dqn::runtime::{
    default_artifact_dir, Device, Manifest, NetArch, Policy, QNet, TrainBatch,
};

/// Deterministic activation-like data: ~25% exact zeros (the post-ReLU
/// sparsity both kernel tiers skip), rest in (-2, 2).
fn det_acts(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if s >> 62 == 0 {
                0.0
            } else {
                ((s >> 40) as f32 / (1u64 << 24) as f32) * 4.0 - 2.0
            }
        })
        .collect()
}

/// Per-conv-layer kernel pairs: the historical im2col pipeline vs the
/// patch-free direct kernel, for forward, input-gradient, and
/// weight-gradient. Deterministic tier only — that's the default path the
/// BENCH trajectory tracks.
fn bench_conv_layers(bench: &mut Bench, net: &str, arch: &NetArch) {
    let hw = arch.conv_out_hw();
    for (i, conv) in arch.convs.iter().enumerate() {
        let (in_h, in_w, in_c) = if i == 0 {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        } else {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        };
        let (oh, ow) = hw[i];
        let (k, s, f) = (conv.kernel, conv.stride, conv.filters);
        let (nrow, kdim) = (oh * ow, k * k * in_c);
        let x = det_acts(in_h * in_w * in_c, 0x5EED ^ i as u64);
        let wmat = det_acts(kdim * f, 0x3A1 ^ i as u64);
        let dy = det_acts(nrow * f, 0x77F ^ i as u64);
        let mut patches = vec![0.0f32; nrow * kdim];
        let mut y = vec![0.0f32; nrow * f];
        let mut dx = vec![0.0f32; in_h * in_w * in_c];
        let mut dw = vec![0.0f32; kdim * f];

        bench.run(&format!("{net}/conv{i}/fwd_im2col"), || {
            im2col_sample(&x, in_h, in_w, in_c, k, s, &mut patches);
            y.fill(0.0);
            matmul_acc_tiled(&patches, &wmat, &mut y, nrow, kdim, f);
            y[0]
        });
        bench.run(&format!("{net}/conv{i}/fwd_direct"), || {
            y.fill(0.0);
            conv2d_forward(&x, &wmat, &mut y, in_h, in_w, in_c, k, s, f);
            y[0]
        });

        bench.run(&format!("{net}/conv{i}/dgrad_im2col"), || {
            matmul_a_bt_tiled(&dy, &wmat, &mut patches, nrow, f, kdim);
            dx.fill(0.0);
            col2im_sample(&patches, in_h, in_w, in_c, k, s, &mut dx);
            dx[0]
        });
        bench.run(&format!("{net}/conv{i}/dgrad_direct"), || {
            dx.fill(0.0);
            conv2d_input_grad(&dy, &wmat, &mut dx, in_h, in_w, in_c, k, s, f);
            dx[0]
        });

        // Weight grad: the im2col arm charges the patch materialization it
        // needs; in the engine those patches had to be retained per sample
        // from the forward pass (the memory cost the direct kernel removes).
        bench.run(&format!("{net}/conv{i}/wgrad_im2col"), || {
            im2col_sample(&x, in_h, in_w, in_c, k, s, &mut patches);
            dw.fill(0.0);
            matmul_at_b_acc_tiled(&patches, &dy, &mut dw, nrow, kdim, f);
            dw[0]
        });
        bench.run(&format!("{net}/conv{i}/wgrad_direct"), || {
            dw.fill(0.0);
            conv2d_weight_grad_chunk(&x, &dy, &mut dw, 0, kdim, in_h, in_w, in_c, k, s, f);
            dw[0]
        });
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let nets: &[&str] = if smoke { &["tiny"] } else { &["tiny", "small"] };

    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir).expect("manifest");
    let device = Arc::new(Device::cpu().unwrap());
    let mut bench = Bench::new();

    let env = make_env("pong", 3).unwrap();
    let mut state = vec![0u8; STATE_BYTES];
    env.write_state(&mut state);

    for &net in nets {
        let arch = NetArch::from_spec(manifest.config(net).expect("spec")).expect("arch");
        bench_conv_layers(&mut bench, net, &arch);
        let qnet = QNet::load(device.clone(), &manifest, net, false, 32).unwrap();
        for b in [1usize, 8, 32] {
            let states: Vec<u8> = state.iter().cycle().take(b * STATE_BYTES).copied().collect();
            bench.run(&format!("{net}/infer_b{b}"), || {
                qnet.infer(Policy::ThetaMinus, &states, b).unwrap()
            });
        }
        let b = 32;
        let batch = TrainBatch {
            states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
            next_states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
            actions: (0..b as i32).map(|i| i % 3).collect(),
            rewards: vec![0.5; b],
            dones: vec![0.0; b],
            ..TrainBatch::default()
        };
        bench.run(&format!("{net}/train_b32"), || qnet.train_step(&batch, 2.5e-4).unwrap());
        bench.run(&format!("{net}/sync_target"), || qnet.sync_target());

        let b1 = bench.get(&format!("{net}/infer_b1")).unwrap().mean_ns;
        let b8 = bench.get(&format!("{net}/infer_b8")).unwrap().mean_ns;
        println!(
            "{net}: 8 size-1 transactions = {:.2} ms vs one size-8 = {:.2} ms ({:.1}x amortization)\n",
            8.0 * b1 / 1e6, b8 / 1e6, 8.0 * b1 / b8
        );
    }
    bench.emit_json("runtime_exec").expect("bench json");
}
