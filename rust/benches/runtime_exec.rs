//! Device-runtime bench: inference at several batch sizes, the batch-32
//! train step, and target sync — the accelerator side of the hardware
//! model. The b1-vs-b8 gap measures the per-transaction overhead that
//! Synchronized Execution amortizes (paper §4).
//!
//! Run: `cargo bench --bench runtime_exec`
//! CI smoke: `cargo bench --bench runtime_exec -- --test`

use std::sync::Arc;

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::{make_env, STATE_BYTES};
use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, Policy, QNet, TrainBatch};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let nets: &[&str] = if smoke { &["tiny"] } else { &["tiny", "small"] };

    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir).expect("manifest");
    let device = Arc::new(Device::cpu().unwrap());
    let mut bench = Bench::new();

    let env = make_env("pong", 3).unwrap();
    let mut state = vec![0u8; STATE_BYTES];
    env.write_state(&mut state);

    for &net in nets {
        let qnet = QNet::load(device.clone(), &manifest, net, false, 32).unwrap();
        for b in [1usize, 8, 32] {
            let states: Vec<u8> = state.iter().cycle().take(b * STATE_BYTES).copied().collect();
            bench.run(&format!("{net}/infer_b{b}"), || {
                qnet.infer(Policy::ThetaMinus, &states, b).unwrap()
            });
        }
        let b = 32;
        let batch = TrainBatch {
            states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
            next_states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
            actions: (0..b as i32).map(|i| i % 3).collect(),
            rewards: vec![0.5; b],
            dones: vec![0.0; b],
            ..TrainBatch::default()
        };
        bench.run(&format!("{net}/train_b32"), || qnet.train_step(&batch, 2.5e-4).unwrap());
        bench.run(&format!("{net}/sync_target"), || qnet.sync_target());

        let b1 = bench.get(&format!("{net}/infer_b1")).unwrap().mean_ns;
        let b8 = bench.get(&format!("{net}/infer_b8")).unwrap().mean_ns;
        println!(
            "{net}: 8 size-1 transactions = {:.2} ms vs one size-8 = {:.2} ms ({:.1}x amortization)\n",
            8.0 * b1 / 1e6, b8 / 1e6, 8.0 * b1 / b8
        );
    }
    bench.emit_json("runtime_exec").expect("bench json");
}
