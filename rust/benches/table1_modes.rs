//! Table 1/2/3 bench: regenerates the paper's runtime grid through the
//! calibrated DES and times the simulator itself.
//!
//! Run: `cargo bench --bench table1_modes`
//! CI smoke: `cargo bench --bench table1_modes -- --test`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::config::ExecMode;
use tempo_dqn::hwsim::{simulate, CostModel, SimRun};
use tempo_dqn::report::RuntimeGrid;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let model = CostModel::gtx1080_i7();
    let threads = [1usize, 2, 4, 8];
    let steps = if smoke { 20_000u64 } else { 200_000u64 };
    let mut bench = Bench::new();
    let mut grid = RuntimeGrid::new(&threads);

    for &w in &threads {
        for mode in ExecMode::ALL {
            let run = SimRun { steps, c: 10_000, f: 4, threads: w, ..SimRun::default() };
            bench.run(&format!("des/{}/w{}", mode.name(), w), || {
                std::hint::black_box(simulate(model, run, mode))
            });
            let stats = simulate(model, run, mode);
            let hours = stats.makespan_ms * (50_000_000.0 / steps as f64) / 3_600_000.0;
            grid.set(mode, w, hours, 0.0);
        }
    }
    println!();
    print!("{}", grid.table1());
    print!("{}", grid.table2());
    print!("{}", grid.table3());
    if let Some((base, best, speedup)) = grid.headline() {
        println!("headline: {base:.2} h -> {best:.2} h ({speedup:.2}x)  [paper: 25.08 -> 9.02, 2.78x]");
    }
    bench.emit_json("table1_modes").expect("bench json");
}
