//! Figure 3 bench: asynchronous vs synchronized execution on the REAL
//! device — transaction counts, bus wait time, and throughput per thread
//! count. Demonstrates the claim that SE's transaction count per step is
//! 1/W while async scales with W and contends.
//!
//! Run: `cargo bench --bench fig3_transactions`
//! CI smoke: `cargo bench --bench fig3_transactions -- --test`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let steps = std::env::var("TEMPO_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 160 } else { 400u64 });
    let widths: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut bench = Bench::new();
    println!("Figure 3 reproduction: device transactions per agent step ({steps} steps, tiny net)");
    println!(
        "{:>14} {:>4} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "mode", "W", "steps", "txns", "txns/step", "wait ms", "steps/s"
    );
    for mode in [ExecMode::Concurrent, ExecMode::Both] {
        for &w in widths {
            let mut cfg = ExperimentConfig::preset("smoke").unwrap();
            cfg.mode = mode;
            cfg.threads = w;
            cfg.total_steps = steps;
            cfg.prepopulate = 300;
            cfg.replay_capacity = 20_000;
            cfg.target_update_period = 200;
            cfg.seed = 3;
            let mut coord = Coordinator::new(cfg, &default_artifact_dir())
                .unwrap()
                .without_eval();
            let res = coord.run().unwrap();
            // One "iteration" = one agent step of the whole run — the
            // wall time is measured by the coordinator, not Bench::run.
            bench.record(&format!("fig3/{}/w{w}/agent_step", mode.name()), res.steps, res.wall_s * 1e9);
            let infer_txns = res.bus.transactions.saturating_sub(res.trains);
            println!(
                "{:>14} {:>4} {:>8} {:>12} {:>12.3} {:>12.1} {:>12.1}",
                mode.name(),
                w,
                res.steps,
                infer_txns,
                infer_txns as f64 / res.steps as f64,
                res.bus.wait_ns as f64 / 1e6,
                res.steps_per_sec
            );
        }
    }
    println!("\nasync (concurrent): ~1 infer transaction per step, independent of W");
    println!("sync (both):        ~1/W infer transactions per step — the Figure 3(b) effect");
    bench.emit_json("fig3_transactions").expect("bench json");
}
