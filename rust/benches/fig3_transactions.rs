//! Figure 3 bench: asynchronous vs synchronized execution on the REAL
//! device — transaction counts, bus wait time, and throughput per thread
//! count. Demonstrates the claim that SE's transaction count per step is
//! 1/W while async scales with W and contends.
//!
//! Run: `cargo bench --bench fig3_transactions`

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;

fn main() {
    let steps = std::env::var("TEMPO_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400u64);
    println!("Figure 3 reproduction: device transactions per agent step ({steps} steps, tiny net)");
    println!(
        "{:>14} {:>4} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "mode", "W", "steps", "txns", "txns/step", "wait ms", "steps/s"
    );
    for mode in [ExecMode::Concurrent, ExecMode::Both] {
        for w in [1usize, 2, 4, 8] {
            let mut cfg = ExperimentConfig::preset("smoke").unwrap();
            cfg.mode = mode;
            cfg.threads = w;
            cfg.total_steps = steps;
            cfg.prepopulate = 300;
            cfg.replay_capacity = 20_000;
            cfg.target_update_period = 200;
            cfg.seed = 3;
            let mut coord = Coordinator::new(cfg, &default_artifact_dir())
                .unwrap()
                .without_eval();
            let res = coord.run().unwrap();
            let infer_txns = res.bus.transactions.saturating_sub(res.trains);
            println!(
                "{:>14} {:>4} {:>8} {:>12} {:>12.3} {:>12.1} {:>12.1}",
                mode.name(),
                w,
                res.steps,
                infer_txns,
                infer_txns as f64 / res.steps as f64,
                res.bus.wait_ns as f64 / 1e6,
                res.steps_per_sec
            );
        }
    }
    println!("\nasync (concurrent): ~1 infer transaction per step, independent of W");
    println!("sync (both):        ~1/W infer transactions per step — the Figure 3(b) effect");
}
