//! Uniform-vs-prioritized replay sampling latency, swept over replay fill
//! (ISSUE 5). Measures the two costs the hwsim cost model splits:
//!
//! * `sample` rows — one 32-minibatch draw + assembly per strategy
//!   (uniform: O(B) RNG draws; proportional: O(B log N) tree descents +
//!   IS-weight math). Feeds `CostModel::sample_ms`.
//! * `update` rows — one batch of TD-priority updates through the
//!   sum-tree (the barrier-side cost prefetch cannot hide). Feeds
//!   `CostModel::tree_ms`.
//!
//! Small frames isolate index/tree cost from frame memcpy; the memcpy
//! half (full-frame push/sample/staging-flush, formerly
//! `benches/replay.rs`) is measured at the end so one target covers the
//! whole replay hot path.
//!
//! Run: `cargo bench --bench replay_sample`
//! CI smoke: `cargo bench --bench replay_sample -- --test`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::config::ReplayStrategy;
use tempo_dqn::env::NET_FRAME;
use tempo_dqn::replay::strategy::StrategyPlan;
use tempo_dqn::replay::{build_strategy, ReplayMemory, SamplingStrategy, StagingBuffer};
use tempo_dqn::runtime::TrainBatch;
use tempo_dqn::util::rng::Rng;

const FRAME: usize = 64; // tiny frames: measure the index, not memcpy
const STACK: usize = 4;
const MINIBATCH: usize = 32;

fn plan(kind: ReplayStrategy) -> StrategyPlan {
    StrategyPlan {
        kind,
        per_alpha: 0.6,
        per_beta0: 0.4,
        per_beta_anneal: 1_000_000,
        n_step: 1,
        gamma: 0.99,
    }
}

fn filled(capacity: usize, prioritized: bool) -> ReplayMemory {
    let mut replay = ReplayMemory::new(capacity, 8, FRAME, STACK, 1).unwrap();
    if prioritized {
        replay.enable_priorities();
    }
    let frame = vec![127u8; FRAME];
    for i in 0..capacity as u64 {
        replay.push((i % 8) as usize, &frame, 1, 0.5, i % 97 == 0, i % 97 == 1 || i < 8);
    }
    replay
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    let fills: &[usize] = if smoke { &[4_096] } else { &[4_096, 65_536, 524_288] };

    let mut bench = Bench::new();
    let mut rng = Rng::new(7);
    for &fill in fills {
        let mut batch = TrainBatch::default();

        // Uniform: one fill_batch per train step (record/apply are no-ops,
        // so this IS the full per-step replay cost).
        let replay_u = filled(fill, false);
        let mut uniform = build_strategy(&plan(ReplayStrategy::Uniform), Rng::new(9).state(), 0);
        let u_ns = bench
            .run(&format!("replay/uniform/sample_b{MINIBATCH}/fill_{fill}"), || {
                uniform.fill_batch(&replay_u, MINIBATCH, &mut batch).unwrap();
            })
            .mean_ns;

        // Proportional, full per-train-step cycle: tree-descent draws +
        // IS weights + assembly, then the batch's priority updates.
        // Synthetic TD errors are pre-generated OUTSIDE the timed loop —
        // the real trainer gets them from the engine for free, so charging
        // RNG + allocation here would inflate the tree_ms calibration.
        let mut replay_p = filled(fill, true);
        let mut per = build_strategy(&plan(ReplayStrategy::Proportional), Rng::new(9).state(), 0);
        let td_pool: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..MINIBATCH).map(|_| rng.f32() * 4.0 - 2.0).collect())
            .collect();
        let mut tick = 0usize;
        let p_cycle_ns = bench
            .run(&format!("replay/proportional/sample_update_b{MINIBATCH}/fill_{fill}"), || {
                per.fill_batch(&replay_p, MINIBATCH, &mut batch).unwrap();
                per.record_td(&td_pool[tick % td_pool.len()]);
                tick += 1;
                per.apply_updates(&mut replay_p);
            })
            .mean_ns;

        // Update half in isolation: 32 guarded sum-tree updates against
        // live leaves (the window-barrier cost prefetch cannot hide).
        let leaves: Vec<usize> = {
            let pi = replay_p.priorities().unwrap();
            (0..replay_p.capacity()).filter(|&l| pi.value(l) > 0.0).collect()
        };
        let priorities: Vec<f64> = (0..977).map(|_| (rng.f64() + 0.01) * 2.0).collect();
        let mut cursor = 0usize;
        let p_update_ns = bench
            .run(&format!("replay/proportional/update_b{MINIBATCH}/fill_{fill}"), || {
                let pi = replay_p.priorities_mut().unwrap();
                for _ in 0..MINIBATCH {
                    let leaf = leaves[cursor % leaves.len()];
                    let gen = pi.gen(leaf);
                    pi.update(leaf, gen, priorities[cursor % priorities.len()]);
                    cursor += 1;
                }
            })
            .mean_ns;

        println!(
            "fill {fill}: uniform {:.1} us | proportional sample+update {:.1} us ({:.2}x) \
             -> tree_ms ~ {:.4} ms (update half), prioritized sample_ms ~ {:.4} ms",
            u_ns / 1e3,
            p_cycle_ns / 1e3,
            p_cycle_ns / u_ns.max(1.0),
            p_update_ns / 1e6,
            (p_cycle_ns - p_update_ns).max(0.0) / 1e6,
        );
    }
    println!(
        "\ntree_ms = the update row (barrier-side, never hidden by prefetch); the rest of \
         the proportional cycle is assembly cost -> CostModel::sample_ms (rust/DESIGN.md §11)"
    );

    // -- full-frame memcpy half (formerly benches/replay.rs) --------------
    // Push / 32-sample / staging-flush at real frame size, where frame
    // copies dominate instead of index math.
    let cap = if smoke { 65_536 } else { 1_000_000 };
    let frame = vec![127u8; NET_FRAME];
    let mut replay = ReplayMemory::new(cap, 8, NET_FRAME, 4, 1).unwrap();
    let mut i = 0u64;
    let push = bench
        .run("replay/push_full_frame", || {
            replay.push((i % 8) as usize, &frame, 1, 0.5, i % 97 == 0, i % 97 == 1);
            i += 1;
        })
        .throughput_per_sec();
    let mut batch = TrainBatch::default();
    let sample = bench
        .run("replay/sample_b32_full_frame", || {
            replay.sample(32, &mut batch).unwrap();
        })
        .throughput_per_sec();
    bench.run("staging/flush_2500", || {
        let mut staging = StagingBuffer::new();
        for k in 0..2_500u32 {
            staging.push(&frame, 1, 0.0, k % 97 == 0, k % 97 == 1);
        }
        staging.flush_into(&mut replay, 0);
    });
    println!(
        "\npush: {:.2} M transitions/s | sample: {:.0} minibatches/s (cap {cap})",
        push / 1e6,
        sample
    );

    bench.emit_json("replay").expect("bench json");
}
