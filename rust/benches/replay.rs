//! Replay-memory bench: push and sample throughput (the L3 hot path that
//! runs once per agent step and once per minibatch).
//!
//! Run: `cargo bench --bench replay`

use tempo_dqn::benchkit::Bench;
use tempo_dqn::env::NET_FRAME;
use tempo_dqn::replay::{ReplayMemory, StagingBuffer};
use tempo_dqn::runtime::TrainBatch;

fn main() {
    let mut bench = Bench::new();
    let frame = vec![127u8; NET_FRAME];

    // Push throughput at DQN-scale capacity.
    let mut replay = ReplayMemory::new(1_000_000, 8, NET_FRAME, 4, 1).unwrap();
    let mut i = 0u64;
    bench.run("replay/push_1M_cap", || {
        replay.push((i % 8) as usize, &frame, 1, 0.5, i % 97 == 0, i % 97 == 1);
        i += 1;
    });

    // Sample throughput (32-minibatch with stack reconstruction).
    let mut batch = TrainBatch::default();
    bench.run("replay/sample_b32", || {
        replay.sample(32, &mut batch).unwrap();
    });

    // Staging flush (Concurrent Training's sync-point cost).
    bench.run("staging/flush_2500", || {
        let mut staging = StagingBuffer::new();
        for k in 0..2_500u32 {
            staging.push(&frame, 1, 0.0, k % 97 == 0, k % 97 == 1);
        }
        staging.flush_into(&mut replay, 0);
    });

    let push = bench.get("replay/push_1M_cap").unwrap();
    let sample = bench.get("replay/sample_b32").unwrap();
    println!(
        "\npush: {:.2} M transitions/s | sample: {:.0} minibatches/s",
        push.throughput_per_sec() / 1e6,
        sample.throughput_per_sec()
    );
}
