//! Checkpoint save/restore latency vs. replay size (rust/DESIGN.md §10).
//!
//! The checkpoint write sits inside a window barrier: the learner is idle
//! from the last `wait_caught_up` until the next window dispatch, so a
//! write that stays under one window's training time (C/F minibatches) is
//! effectively free. This bench measures the dominant cost — serializing
//! and re-loading the replay ring — across fill levels, plus the qnet
//! parameter snapshot, so that budget can be checked against Table 1-style
//! window times.
//!
//! Run: `cargo bench --bench checkpoint`
//! CI smoke: `cargo bench --bench checkpoint -- --test`

use tempo_dqn::ckpt::{ByteReader, ByteWriter, CheckpointWriter, Snapshot};
use tempo_dqn::env::NET_FRAME;
use tempo_dqn::replay::ReplayMemory;
use tempo_dqn::benchkit::Bench;
use tempo_dqn::util::rng::Rng;

fn filled_replay(frames: usize, streams: usize) -> ReplayMemory {
    let mut replay = ReplayMemory::new(frames, streams, NET_FRAME, 4, 7).unwrap();
    let mut rng = Rng::new(1);
    let mut frame = vec![0u8; NET_FRAME];
    for i in 0..frames {
        // Non-constant content so serialization cost is realistic.
        frame[i % NET_FRAME] = rng.below(256) as u8;
        replay.push(i % streams, &frame, (i % 4) as u8, 0.5, i % 97 == 96, i % 97 == 0);
    }
    replay
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        std::env::set_var("TEMPO_BENCH_MS", "60");
    }
    // Fill levels in stored frames (1M-frame DQN scale is ~7 GB of state;
    // the full-scale point is opt-in via the non-smoke run).
    let sizes: &[usize] = if smoke { &[2_000, 20_000] } else { &[2_000, 20_000, 200_000] };
    let streams = 8;

    let mut bench = Bench::new();
    println!("checkpoint serialization cost vs replay size ({streams} streams):\n");
    for &frames in sizes {
        let replay = filled_replay(frames, streams);
        let name_save = format!("ckpt/replay_save_{frames}");
        let save_ns = bench
            .run(&name_save, || {
                let mut w = ByteWriter::with_capacity(frames * NET_FRAME + 1024);
                replay.save(&mut w);
                w.into_bytes().len()
            })
            .mean_ns;
        let bytes = frames * NET_FRAME;
        println!(
            "  save   {frames:>7} frames ({:>7.1} MB): {:>9.2} ms  ({:.2} GB/s)",
            bytes as f64 / 1e6,
            save_ns / 1e6,
            bytes as f64 / save_ns.max(1.0)
        );

        let mut w = ByteWriter::new();
        replay.save(&mut w);
        let blob = w.into_bytes();
        let mut target = ReplayMemory::new(frames, streams, NET_FRAME, 4, 7).unwrap();
        let name_load = format!("ckpt/replay_load_{frames}");
        let load_ns = bench
            .run(&name_load, || {
                let mut r = ByteReader::new(&blob);
                target.load(&mut r).unwrap();
            })
            .mean_ns;
        println!(
            "  load   {frames:>7} frames ({:>7.1} MB): {:>9.2} ms",
            bytes as f64 / 1e6,
            load_ns / 1e6
        );
    }

    // End-to-end directory write (manifest + checksums + atomic rename) at
    // the smallest size — the fixed overhead on top of serialization.
    let replay = filled_replay(sizes[0], streams);
    let dir = std::env::temp_dir().join(format!("tempo-ckpt-bench-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    let e2e_ns = bench
        .run("ckpt/dir_write_e2e", || {
            let mut wtr = CheckpointWriter::new(0);
            wtr.add(&replay).unwrap();
            wtr.write(&dir).unwrap()
        })
        .mean_ns;
    println!("\n  atomic dir write ({} frames): {:.2} ms", sizes[0], e2e_ns / 1e6);
    let _ = std::fs::remove_dir_all(&dir);

    // Budget check hint: one training window at paper scale is C/F = 2500
    // minibatches; the checkpoint write must stay under that wall time.
    println!("\n(checkpoint writes happen inside the window barrier; keep them under one window)");
    bench.emit_json("checkpoint").expect("bench json");
}
