//! Vendored offline subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace ships
//! the slice of anyhow's API that tempo-dqn actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait. Semantics match upstream for that slice:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `context`/`with_context` push a new high-level message onto the chain;
//! * `{e}` displays the outermost message, `{e:#}` the whole chain
//!   (`outer: inner: root`), and `{e:?}` a multi-line report.

use std::fmt;

/// Chain-of-messages error value (outermost message first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a higher-level context message onto the front of the chain.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost (most recently added) message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// Messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, exactly
// like upstream anyhow — that is what keeps the blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — alias with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from a message, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with a formatted [`Error`], like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest").context("loading artifacts");
        assert_eq!(format!("{e}"), "loading artifacts");
        assert_eq!(format!("{e:#}"), "loading artifacts: reading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            let _ = std::str::from_utf8(&[0xFF])?; // foreign error through `?`
            Ok(())
        }
        assert!(inner().is_err());
        let e = anyhow!("step {} failed", 3);
        assert_eq!(e.to_string(), "step 3 failed");
        fn bails() -> Result<u32> {
            bail!("no dice");
        }
        assert_eq!(bails().unwrap_err().to_string(), "no dice");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening: missing file");
        let o: Option<u8> = None;
        assert_eq!(o.with_context(|| "empty").unwrap_err().to_string(), "empty");
    }
}
