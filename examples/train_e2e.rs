//! End-to-end training driver: proves all three layers compose.
//!
//! Trains the `small` CNN (~678k params; same architecture family as the
//! paper's 1.7M-param Nature network) with the full Algorithm-1 coordinator
//! (Concurrent Training + Synchronized Execution, W sampler threads) on a
//! synthetic pixel game, logging the loss curve and episode returns, then
//! evaluating the learned policy against the Random anchor.
//!
//! Run with: `cargo run --release --example train_e2e -- [--steps N]
//!            [--game seeker] [--net small] [--threads 4]
//!            [--envs-per-thread B]`

use tempo_dqn::config::{EpsSchedule, ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::eval::{AnchorKind, Evaluator};
use tempo_dqn::runtime::default_artifact_dir;
use tempo_dqn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 4_000)?;
    let game = args.get_or("game", "seeker").to_string();
    let net = args.get_or("net", "small").to_string();
    let threads = args.usize_or("threads", 4)?;
    let envs_per_thread = args.usize_or("envs-per-thread", 1)?;

    let mut cfg = ExperimentConfig::preset("paper")?;
    cfg.game = game.clone();
    cfg.net = net.clone();
    cfg.mode = ExecMode::Both;
    cfg.threads = threads;
    cfg.envs_per_thread = envs_per_thread;
    cfg.total_steps = steps;
    cfg.seed = 7;
    cfg.replay_capacity = 120_000;
    cfg.prepopulate = 1_500;
    cfg.target_update_period = 500;
    cfg.eps = EpsSchedule { start: 1.0, end: 0.1, decay_steps: steps * 3 / 4 };
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    cfg.eval_period = u64::MAX; // final eval below instead

    println!(
        "=== tempo-dqn end-to-end: {net} net, {game}, Algorithm 1, W={threads} B={envs_per_thread}, {steps} steps ==="
    );
    let mut coord = Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
    let res = coord.run()?;

    println!("\n-- run summary --");
    println!(
        "steps {}  wall {:.1}s  ({:.1} steps/s)  episodes {}  trains {}  syncs {}",
        res.steps, res.wall_s, res.steps_per_sec, res.episodes, res.trains, res.target_syncs
    );
    println!(
        "device: {} transactions, busy {:.1}s, wait {:.1}s",
        res.bus.transactions,
        res.bus.busy_ns as f64 / 1e9,
        res.bus.wait_ns as f64 / 1e9
    );
    print!("{}", res.timers_report);

    println!("\n-- loss curve (TD loss, sampled every 16 updates) --");
    let stride = (res.losses.len() / 20).max(1);
    for chunk in res.losses.chunks(stride) {
        let (step, _) = chunk[0];
        let mean: f32 = chunk.iter().map(|(_, l)| *l).sum::<f32>() / chunk.len() as f32;
        println!("  step {step:>8}: loss {mean:.5}");
    }

    println!("\n-- episode returns (raw) --");
    let stride = (res.returns.len() / 15).max(1);
    for chunk in res.returns.chunks(stride) {
        let (step, _) = chunk[0];
        let mean: f64 = chunk.iter().map(|(_, r)| *r).sum::<f64>() / chunk.len() as f64;
        println!("  step {step:>8}: return {mean:.2}");
    }
    let early = res.returns.iter().take(10).map(|(_, r)| *r).sum::<f64>()
        / res.returns.len().min(10).max(1) as f64;
    let late = res.recent_mean_return(10);

    println!("\n-- final evaluation (eps=0.05) vs anchors --");
    let mut ev = Evaluator::new(&game, 1234, 5, 0.05)?.with_max_steps(1_500);
    let random = ev.run_anchor(AnchorKind::Random)?;
    let expert = ev.run_anchor(AnchorKind::Expert)?;
    let learned = ev.run(coord.qnet(), res.steps)?;
    println!("  random policy : {:.2} ± {:.2}", random.mean_return, random.std_return);
    println!("  human-proxy   : {:.2} ± {:.2}", expert.mean_return, expert.std_return);
    println!("  learned policy: {:.2} ± {:.2}", learned.mean_return, learned.std_return);
    println!(
        "  human-normalized: {:.1}%",
        tempo_dqn::eval::normalized_score(
            learned.mean_return, random.mean_return, expert.mean_return)
    );
    println!("\ntraining return trend: early {early:.2} -> late {late:.2}");
    if learned.mean_return > random.mean_return {
        println!("RESULT: learned policy beats the random anchor ✓");
    } else {
        println!("RESULT: learned policy did not beat random at this budget (expected for very short runs)");
    }
    Ok(())
}
