//! Quickstart: the smallest complete tour of the public API.
//!
//! Loads the Q-network (AOT artifacts when present, otherwise the builtin
//! manifest on the native engine), creates a synthetic Atari-like
//! environment, runs greedy inference, performs one training step from a
//! replay minibatch, and syncs the target network.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use tempo_dqn::env::{make_env, NET_FRAME, STACK, STATE_BYTES};
use tempo_dqn::agent::{argmax, EpsGreedy};
use tempo_dqn::replay::ReplayMemory;
use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, Policy, QNet, TrainBatch};

fn main() -> anyhow::Result<()> {
    // 1. Load the compiled Q-network (tiny config, batch-32 train entry).
    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir)?;
    let device = Arc::new(Device::cpu()?);
    let qnet = QNet::load(device.clone(), &manifest, "tiny", false, 32)?;
    println!(
        "loaded {:?}: {} params, {} actions, platform {}",
        qnet.spec().name,
        qnet.spec().param_count,
        qnet.spec().actions,
        device.platform_name()
    );

    // 2. Interact with an environment using the greedy policy.
    let mut env = make_env("pong", 42)?;
    let mut policy = EpsGreedy::new(42, 0, env.num_actions());
    let mut state = vec![0u8; STATE_BYTES];
    let mut replay = ReplayMemory::new(10_000, 1, NET_FRAME, STACK, 42)?;
    let mut frame = vec![0u8; NET_FRAME];
    let mut start = true;
    for step in 0..64 {
        env.write_state(&mut state);
        let q = qnet.infer(Policy::ThetaMinus, &state, 1)?;
        let action = policy.select(&q, 0.1); // epsilon-greedy, eps = 0.1
        frame.copy_from_slice(env.latest_plane());
        let r = env.step(action);
        replay.push(0, &frame, action as u8, r.reward, r.done, start);
        start = false;
        if step == 0 {
            println!("q-values at t=0: {q:?} -> greedy action {}", argmax(&q));
        }
        if r.done {
            env.reset();
            start = true;
        }
    }
    println!("collected {} transitions ({} sampleable)", replay.len(), replay.sampleable());

    // 3. One training step from a sampled minibatch.
    let mut batch = TrainBatch::default();
    replay.sample(32, &mut batch)?;
    let loss = qnet.train_step(&batch, 2.5e-4)?;
    println!("train step: loss = {loss:.5}");

    // 4. Target-network sync (theta_minus <- theta).
    qnet.sync_target();
    println!("target synced; device transactions so far: {}",
             device.stats.snapshot().transactions);
    Ok(())
}
