//! B-sweep: measure the W×B speedup of vectorized environment streams.
//!
//! Runs the full Algorithm-1 coordinator (mode `both`) at a fixed thread
//! count W while sweeping B = envs-per-thread, reporting wall-clock
//! steps/s, device transactions, and the per-transaction batch. This is
//! the experiment the ISSUE's tentpole enables: one device transaction
//! serving W×B environment steps instead of W (rust/DESIGN.md §5).
//!
//! Run: `cargo run --release --example b_sweep -- [--threads 2]
//!       [--envs 1,2,4,8] [--steps 2000] [--game seeker] [--mode both]`

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::runtime::default_artifact_dir;
use tempo_dqn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let threads = args.usize_or("threads", 2)?;
    let sweep = args.usize_list_or("envs", &[1, 2, 4, 8])?;
    let steps = args.u64_or("steps", 2_000)?;
    let game = args.get_or("game", "seeker").to_string();
    let mode = ExecMode::parse(args.get_or("mode", "both"))?;

    println!("== B-sweep: mode={} W={threads} {steps} steps on {game} ==", mode.name());
    println!(
        "{:>4} {:>8} {:>12} {:>14} {:>12} {:>14}",
        "B", "streams", "steps/s", "transactions", "txn/step", "infer batch"
    );
    let mut base_rate = None;
    for &b in &sweep {
        let mut cfg = ExperimentConfig::preset("smoke")?;
        cfg.game = game.clone();
        cfg.mode = mode;
        cfg.threads = threads;
        cfg.envs_per_thread = b;
        cfg.total_steps = steps;
        cfg.seed = 7;
        cfg.prepopulate = 500;
        cfg.replay_capacity = 60_000;
        cfg.target_update_period = 256;
        let mut coord = Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
        let res = coord.run()?;
        let rate = res.steps_per_sec;
        let speedup = match base_rate {
            None => {
                base_rate = Some(rate);
                String::from("1.00x (base)")
            }
            Some(base) => format!("{:.2}x", rate / base),
        };
        println!(
            "{:>4} {:>8} {:>12.1} {:>14} {:>12.3} {:>14}  {speedup}",
            b,
            threads * b,
            rate,
            res.bus.transactions,
            res.bus.transactions as f64 / res.steps as f64,
            threads * b,
        );
    }
    println!("\nsynchronized modes: txn/step ~ 1/(W*B) + 1/F (training transactions)");
    Ok(())
}
