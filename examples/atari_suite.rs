//! Table 4 analog: train standard-DQN and tempo-dqn on every game in the
//! synthetic suite and report Random / Human-proxy / DQN / Ours scores with
//! human-normalized percentages (paper §5.2 / Appendix A).
//!
//! The real Table 4 trains 50M steps per game on ALE; this driver runs a
//! budgeted analog (default a few thousand steps per game on the tiny net)
//! so the whole suite finishes in minutes on one CPU core. Raise --steps /
//! --net for a longer, more faithful run.
//!
//! Run: `cargo run --release --example atari_suite -- [--steps N]
//!       [--games pong,seeker] [--net tiny] [--threads 4] [--episodes N]`

use tempo_dqn::config::{EpsSchedule, ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::env::GAMES;
use tempo_dqn::eval::{AnchorKind, Evaluator};
use tempo_dqn::report::{table4, GameRow};
use tempo_dqn::runtime::default_artifact_dir;
use tempo_dqn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let games: Vec<String> = match args.str_opt("games") {
        Some(list) => list.split(',').map(str::to_string).collect(),
        None => GAMES.iter().map(|s| s.to_string()).collect(),
    };
    let steps = args.u64_or("steps", 2_500)?;
    let threads = args.usize_or("threads", 4)?;
    let episodes = args.usize_or("episodes", 4)?;
    let max_steps = args.usize_or("max-steps", 1_200)?;
    let net = args.get_or("net", "tiny").to_string();

    let train_score = |game: &str, mode: ExecMode, w: usize| -> anyhow::Result<f64> {
        let mut cfg = ExperimentConfig::preset("smoke")?;
        cfg.game = game.to_string();
        cfg.net = net.clone();
        cfg.mode = mode;
        cfg.threads = w;
        cfg.total_steps = steps;
        cfg.seed = 5;
        cfg.prepopulate = (steps as usize / 3).clamp(200, 2_000);
        cfg.replay_capacity = 150_000;
        cfg.target_update_period = (steps / 8).clamp(100, 2_000) / 4 * 4;
        cfg.eps = EpsSchedule { start: 1.0, end: 0.1, decay_steps: steps * 3 / 4 };
        cfg.lr = args.f64_or("lr", 1e-3)?; // budgeted runs learn faster hot
        let mut coord = Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
        coord.run()?;
        let mut ev = Evaluator::new(game, 99, episodes, 0.05)?.with_max_steps(max_steps);
        Ok(ev.run(coord.qnet(), steps)?.mean_return)
    };

    let mut rows = Vec::new();
    for game in &games {
        eprintln!("[suite] {game}: measuring anchors...");
        let mut ev = Evaluator::new(game, 7, episodes, 0.05)?.with_max_steps(max_steps);
        let random = ev.run_anchor(AnchorKind::Random)?;
        let human = ev.run_anchor(AnchorKind::Expert)?;
        eprintln!("[suite] {game}: training standard-DQN baseline (W=1)...");
        let baseline = train_score(game, ExecMode::Standard, 1)?;
        eprintln!("[suite] {game}: training tempo-dqn (Algorithm 1, W={threads})...");
        let ours = train_score(game, ExecMode::Both, threads)?;
        rows.push(GameRow { game: game.clone(), random, human, baseline_dqn: baseline, ours });
    }
    print!("{}", table4(&rows));
    Ok(())
}
