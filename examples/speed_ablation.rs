//! Speed-test ablation (paper §5.1): regenerates Tables 1, 2, 3 and the
//! Figure 2 timing diagram.
//!
//! Three stages:
//!  1. `--calibrate`  measure THIS machine's per-op costs (env step, infer
//!     at several batch sizes, train) and build a measured cost model.
//!  2. DES sweep over {mode} x {threads} under both the paper-fitted
//!     GTX 1080 model and (optionally) the measured model.
//!  3. `--real`  run scaled live experiments for every grid cell and print
//!     the same tables from wall-clock (validates the DES inputs).
//!  4. `--gantt` print the measured Figure-2-style timing diagram.
//!
//! Run: `cargo run --release --example speed_ablation -- [--real] [--gantt]
//!       [--threads 1,2,4,8] [--steps N] [--trials N]`

use std::sync::Arc;
use std::time::Instant;

use tempo_dqn::config::{ExecMode, ExperimentConfig};
use tempo_dqn::coordinator::Coordinator;
use tempo_dqn::env::{make_env, STATE_BYTES};
use tempo_dqn::hwsim::{simulate, CostModel, SimRun};
use tempo_dqn::metrics::GanttTrace;
use tempo_dqn::report::RuntimeGrid;
use tempo_dqn::runtime::{default_artifact_dir, Device, Manifest, Policy, QNet, TrainBatch};
use tempo_dqn::util::cli::Args;

fn measure_costs(net: &str) -> anyhow::Result<CostModel> {
    println!("-- calibration: measuring per-op costs on this machine ({net} net) --");
    let dir = default_artifact_dir();
    let manifest = Manifest::load_or_builtin(&dir)?;
    let device = Arc::new(Device::cpu()?);
    let qnet = QNet::load(device.clone(), &manifest, net, false, 32)?;

    // Env step cost (simulate + render + preprocess).
    let mut env = make_env("pong", 3)?;
    let t0 = Instant::now();
    let iters = 400;
    for i in 0..iters {
        if env.step(i % env.num_actions()).done {
            env.reset();
        }
    }
    let env_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

    // Inference at batch 1 and 8.
    let mut state = vec![0u8; STATE_BYTES];
    env.write_state(&mut state);
    let infer_ms = |b: usize| -> anyhow::Result<f64> {
        let states: Vec<u8> = state.iter().cycle().take(b * STATE_BYTES).copied().collect();
        qnet.infer(Policy::ThetaMinus, &states, b)?; // warm
        let t0 = Instant::now();
        let n = 30;
        for _ in 0..n {
            qnet.infer(Policy::ThetaMinus, &states, b)?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1e3 / n as f64)
    };
    let i1 = infer_ms(1)?;
    let i8 = infer_ms(8)?;

    // Train step.
    let b = 32;
    let batch = TrainBatch {
        states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
        next_states: state.iter().cycle().take(b * STATE_BYTES).copied().collect(),
        actions: (0..b as i32).map(|i| i % 3).collect(),
        rewards: vec![0.5; b],
        dones: vec![0.0; b],
        ..TrainBatch::default()
    };
    qnet.train_step(&batch, 2.5e-4)?; // warm
    let t0 = Instant::now();
    let n = 10;
    for _ in 0..n {
        qnet.train_step(&batch, 2.5e-4)?;
    }
    let train_ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;

    println!(
        "  env {env_ms:.3} ms | infer b1 {i1:.3} ms, b8 {i8:.3} ms | train b32 {train_ms:.3} ms"
    );
    Ok(CostModel::from_measured(env_ms, i1, i8, train_ms, 1))
}

fn des_tables(model: CostModel, label: &str, threads: &[usize], steps: u64) {
    let mut grid = RuntimeGrid::new(threads);
    for &w in threads {
        for mode in ExecMode::ALL {
            let run = SimRun { steps, c: 10_000, f: 4, threads: w, ..SimRun::default() };
            let stats = simulate(model, run, mode);
            let hours = stats.makespan_ms * (50_000_000.0 / steps as f64) / 3_600_000.0;
            grid.set(mode, w, hours, 0.0);
        }
    }
    println!("== DES tables ({label}; scaled to 50M steps) ==");
    print!("{}", grid.table1());
    print!("{}", grid.table2());
    print!("{}", grid.table3());
    if let Some((base, best, speedup)) = grid.headline() {
        println!("headline: {base:.2} h -> {best:.2} h ({speedup:.2}x)");
    }
    println!(
        "paper:    25.08 h -> 9.02 h (2.78x)  [Table 1, GTX 1080 + i7-7700K]\n"
    );
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let threads = args.usize_list_or("threads", &[1, 2, 4, 8])?;
    let net = args.get_or("net", "tiny").to_string();

    // Paper-machine DES (the Table 1-3 reproduction).
    des_tables(CostModel::gtx1080_i7(), "paper-fitted GTX 1080 cost model",
               &threads, args.u64_or("sim-steps", 1_000_000)?);

    if args.flag("calibrate") || args.flag("real") {
        let measured = measure_costs(&net)?;
        des_tables(measured, "measured on this machine", &threads,
                   args.u64_or("sim-steps", 100_000)?);

        if args.flag("real") {
            let steps = args.u64_or("steps", 1_500)?;
            let trials = args.usize_or("trials", 1)?;
            println!("== real scaled runs ({steps} steps x {trials} trials, {net} net) ==");
            let mut grid = RuntimeGrid::new(&threads);
            for &w in &threads {
                for mode in ExecMode::ALL {
                    let mut samples = Vec::new();
                    for trial in 0..trials {
                        let mut cfg = ExperimentConfig::preset("speedtest")?;
                        cfg.net = net.clone();
                        cfg.mode = mode;
                        cfg.threads = w;
                        cfg.seed = trial as u64;
                        cfg.total_steps = steps;
                        cfg.prepopulate = 500;
                        cfg.replay_capacity = 50_000;
                        cfg.target_update_period = 500;
                        let mut coord =
                            Coordinator::new(cfg, &default_artifact_dir())?.without_eval();
                        let res = coord.run()?;
                        samples.push(res.wall_s / 3_600.0);
                    }
                    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
                    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
                        / samples.len() as f64;
                    println!("  {:>12} W={w}: {:.2}s", mode.name(), mean * 3600.0);
                    grid.set(mode, w, mean, var.sqrt());
                }
            }
            print!("{}", grid.table1());
            print!("{}", grid.table3());
        }
    }

    if args.flag("gantt") {
        for mode in [ExecMode::Standard, ExecMode::Both] {
            println!("== measured timing diagram: {} (Figure 2 analog) ==", mode.name());
            let gantt = Arc::new(GanttTrace::new(200_000));
            let mut cfg = ExperimentConfig::preset("smoke")?;
            cfg.mode = mode;
            cfg.threads = 4;
            cfg.total_steps = 192;
            cfg.target_update_period = 64;
            let mut coord =
                Coordinator::new(cfg, &default_artifact_dir())?.with_gantt(gantt.clone());
            coord.run()?;
            print!("{}", gantt.render_ascii(96));
        }
    }
    Ok(())
}
